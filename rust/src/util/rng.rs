//! Deterministic PCG64 (XSL-RR 128/64) random number generator.
//!
//! All randomness in the simulator, trace generator, and property tests
//! flows through this type, seeded per experiment, so every run is
//! bit-reproducible (DESIGN.md §6 "Determinism").

/// PCG XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience constructor with stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Used for Poisson
    /// inter-arrival times in the dynamic traces (paper §5.1).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Poisson-distributed count with mean `lambda` (Knuth for small
    /// lambda, normal approximation above 30).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = lambda + lambda.sqrt() * self.normal();
            if x < 0.0 {
                0
            } else {
                x.round() as u64
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all-zero weights");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Derive an independent child generator (for per-job streams).
    pub fn fork(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Pcg64::seeded(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean_matches() {
        let mut rng = Pcg64::seeded(13);
        for &lam in &[0.5, 4.0, 50.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| rng.poisson(lam) as f64).sum::<f64>()
                / n as f64;
            assert!((mean - lam).abs() < lam.max(1.0) * 0.05,
                    "lam={lam} mean={mean}");
        }
    }

    #[test]
    fn exponential_mean_matches() {
        let mut rng = Pcg64::seeded(15);
        let n = 50_000;
        let mean = (0..n).map(|_| rng.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(17);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut rng = Pcg64::seeded(19);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.7..3.3).contains(&ratio), "{counts:?}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg64::seeded(21);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
