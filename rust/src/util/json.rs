//! Minimal JSON implementation (serde is unavailable offline).
//!
//! Used for (a) reading `artifacts/*.meta.json` sidecars produced by the
//! python AOT step, (b) the deploy-mode wire protocol, and (c) dumping
//! experiment results. Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP (sufficient for our ASCII metadata).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic encoding.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Encode to a compact JSON string.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Inf; encode as null (decoded back as
                    // NaN via `as_f64`). Keeps e.g. unset losses on the
                    // deploy wire protocol from corrupting frames.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(xs: Vec<Json>) -> Json {
        Json::Arr(xs)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump()
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        s.push(char::from_u32(code)
                            .ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(xs)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.encode()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(
            r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": 1e3}"#,
        )
        .unwrap();
        assert_eq!(v.get("d").as_f64(), Some(1000.0));
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo→"));
        assert_eq!(Json::parse(&v.encode()).unwrap(), v);
    }

    #[test]
    fn integers_encode_without_fraction() {
        assert_eq!(Json::Num(5.0).encode(), "5");
        assert_eq!(Json::Num(5.5).encode(), "5.5");
    }

    #[test]
    fn non_finite_numbers_encode_as_null() {
        // JSON has no NaN/Inf; frames carrying them must stay parseable
        // (regression: a NaN loss in a deploy Progress frame killed the
        // leader's reader thread).
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let enc = Json::Num(v).encode();
            assert_eq!(enc, "null");
            assert!(Json::parse(&enc).is_ok());
        }
        let frame = Json::obj(vec![("loss", Json::num(f64::NAN))]).encode();
        assert!(Json::parse(&frame).is_ok(), "{frame}");
    }

    #[test]
    fn reads_real_meta_sidecar_format() {
        let doc = r#"{"variant": "tiny", "param_count": 123456,
                      "train_inputs": [{"name": "flat_params",
                                        "shape": [123456], "dtype": "f32"}]}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("param_count").as_usize(), Some(123456));
        let inputs = v.get("train_inputs").as_arr().unwrap();
        assert_eq!(inputs[0].get("dtype").as_str(), Some("f32"));
    }
}
