//! Wall-clock bench harness (criterion is unavailable offline).
//!
//! Every `cargo bench` target in `rust/benches/` uses `harness = false`
//! and drives this module: [`Bench::iter`] warms up, runs timed
//! iterations, and prints median/mean/p95 per case in a stable,
//! grep-friendly format that EXPERIMENTS.md quotes directly.

use std::time::{Duration, Instant};

/// Result of timing one case.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
}

impl Timing {
    pub fn report(&self) {
        println!(
            "bench {:<44} iters={:<5} median={:>12?} mean={:>12?} p95={:>12?}",
            self.name, self.iters, self.median, self.mean, self.p95
        );
    }
}

/// Bench runner with configurable warmup/measurement budget.
pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            budget: Duration::from_secs(3),
        }
    }
}

impl Bench {
    /// Quick preset for expensive end-to-end cases.
    pub fn heavy() -> Self {
        Bench {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            budget: Duration::from_secs(5),
        }
    }

    /// Time `f`, returning stats. The closure's return value is
    /// black-boxed to prevent the optimizer from deleting the work.
    pub fn iter<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Timing {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters && start.elapsed() < self.budget)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        let p95_idx =
            ((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1);
        let p95 = samples[p95_idx];
        let timing = Timing {
            name: name.to_string(),
            iters: samples.len(),
            median,
            mean,
            p95,
        };
        timing.report();
        timing
    }
}

/// Print a table row in the format used by the figure benches:
/// `row <figure> <series> x=<x> y=<y> [extra]`.
pub fn row(figure: &str, series: &str, x: f64, y: f64, extra: &str) {
    if extra.is_empty() {
        println!("row {figure:<18} {series:<24} x={x:<10} y={y:.4}");
    } else {
        println!("row {figure:<18} {series:<24} x={x:<10} y={y:.4} {extra}");
    }
}

/// Print a bench section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_runs_and_reports() {
        let b = Bench {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 5,
            budget: Duration::from_millis(50),
        };
        let t = b.iter("noop", || 1 + 1);
        assert!(t.iters >= 3 && t.iters <= 5);
        assert!(t.median <= t.p95);
    }
}
