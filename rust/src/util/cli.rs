//! Flag-style CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and a
//! positional subcommand, which covers every binary in this crate.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    pub options: BTreeMap<String, String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.options.insert(stripped.to_string(), "true".into());
                }
            } else if args.command.is_none() {
                args.command = Some(a);
            } else {
                args.positionals.push(a);
            }
        }
        args
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("simulate --gpus 128 --policy srtf --verbose");
        assert_eq!(a.command.as_deref(), Some("simulate"));
        assert_eq!(a.usize("gpus", 0), 128);
        assert_eq!(a.get("policy"), Some("srtf"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("run --load=5.5 --out=/tmp/x");
        assert_eq!(a.f64("load", 0.0), 5.5);
        assert_eq!(a.get("out"), Some("/tmp/x"));
    }

    #[test]
    fn positionals_collected() {
        let a = parse("bench fig1 fig2");
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.positionals, vec!["fig1", "fig2"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x");
        assert_eq!(a.usize("servers", 16), 16);
        assert_eq!(a.f64("ratio", 3.0), 3.0);
        assert_eq!(a.get_or("policy", "fifo"), "fifo");
    }

    #[test]
    fn boolean_flag_before_another_flag() {
        let a = parse("cmd --dry-run --gpus 4");
        assert!(a.flag("dry-run"));
        assert_eq!(a.usize("gpus", 0), 4);
    }
}
