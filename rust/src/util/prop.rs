//! Property-based testing mini-framework (proptest is unavailable offline).
//!
//! A property is a closure over a [`Gen`] (a seeded random source with
//! convenience samplers). [`check`] runs it for `cases` seeds and, on
//! failure, retries with progressively *smaller* size hints to report the
//! smallest failing seed it can find (size-directed shrinking: generators
//! consult `g.size` so smaller sizes produce structurally smaller inputs).
//!
//! Used by the coordinator/mechanism invariant tests (DESIGN.md §6).

use super::rng::Pcg64;

/// Random-input generator handed to properties.
pub struct Gen {
    pub rng: Pcg64,
    /// Size hint in [0.0, 1.0]; generators should scale structure size by it.
    pub size: f64,
}

impl Gen {
    /// Integer in [lo, hi), biased smaller as `size` shrinks.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        let span = ((hi - lo) as f64 * self.size).max(1.0) as usize;
        self.rng.range(lo, lo + span.min(hi - lo).max(1))
    }

    /// f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Boolean with probability 1/2.
    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vector of `n` items where n scales with size.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T)
        -> Vec<T>
    {
        let n = self.int(0, max_len + 1);
        (0..n).map(|_| f(self)).collect()
    }

    /// Pick one of the provided values.
    pub fn choose<T: Clone>(&mut self, xs: &[T]) -> T {
        xs[self.rng.range(0, xs.len())].clone()
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub struct Failure {
    pub seed: u64,
    pub size: f64,
    pub message: String,
}

/// Run `prop` for `cases` random cases. Panics with the smallest failing
/// case found (seed + size are printed so the failure is reproducible).
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    let mut failure: Option<Failure> = None;
    for seed in 0..cases {
        let mut g = Gen { rng: Pcg64::new(seed, 0xC0FFEE), size: 1.0 };
        if let Err(message) = prop(&mut g) {
            failure = Some(Failure { seed, size: 1.0, message });
            break;
        }
    }
    let Some(mut fail) = failure else { return };

    // Size-directed shrink: replay the failing seed at smaller sizes, then
    // scan nearby seeds at the smallest size that still fails.
    for &size in &[0.5, 0.25, 0.1, 0.05] {
        let mut g = Gen { rng: Pcg64::new(fail.seed, 0xC0FFEE), size };
        if let Err(message) = prop(&mut g) {
            fail = Failure { seed: fail.seed, size, message };
        }
    }
    panic!(
        "property '{name}' failed (seed={}, size={}): {}",
        fail.seed, fail.size, fail.message
    );
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_silent() {
        check("sort is idempotent", 50, |g| {
            let mut v = g.vec(64, |g| g.int(0, 1000));
            v.sort_unstable();
            let once = v.clone();
            v.sort_unstable();
            prop_assert!(v == once, "double sort changed data");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        check("int bounds", 100, |g| {
            let x = g.int(3, 10);
            prop_assert!((3..10).contains(&x), "out of range: {x}");
            let f = g.f64(-1.0, 1.0);
            prop_assert!((-1.0..1.0).contains(&f), "f out of range: {f}");
            Ok(())
        });
    }

    #[test]
    fn smaller_size_produces_smaller_vectors() {
        let mut big = Gen { rng: Pcg64::seeded(1), size: 1.0 };
        let mut small = Gen { rng: Pcg64::seeded(1), size: 0.05 };
        let avg_big: f64 = (0..100)
            .map(|_| big.vec(100, |g| g.bool()).len() as f64)
            .sum::<f64>() / 100.0;
        let avg_small: f64 = (0..100)
            .map(|_| small.vec(100, |g| g.bool()).len() as f64)
            .sum::<f64>() / 100.0;
        assert!(avg_small < avg_big / 3.0, "{avg_small} vs {avg_big}");
    }
}
