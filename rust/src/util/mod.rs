//! Zero-dependency substrates.
//!
//! This crate builds in a fully offline environment where only the `xla`
//! crate's dependency closure is vendored, so the usual ecosystem crates
//! (rand, serde, clap, criterion, proptest) are unavailable. Everything
//! they would have provided is implemented here as small, tested modules:
//!
//! - [`rng`] — deterministic PCG64 RNG (uniform/normal/poisson/exp/shuffle)
//! - [`stats`] — mean/percentile/CDF/histogram helpers
//! - [`json`] — JSON parse + serialize (artifact metadata, wire protocol)
//! - [`cli`] — flag-style argument parser
//! - [`prop`] — property-based testing harness (random cases + shrinking)
//! - [`bench`] — wall-clock bench harness used by `cargo bench` targets
//! - [`fsx`] — parent-creating file writes with path-naming errors (CLI
//!   report/telemetry outputs)

pub mod bench;
pub mod cli;
pub mod fsx;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
