//! Statistics helpers for metrics reporting (JCT percentiles, CDFs).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on the sorted data.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile out of range");
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Empirical CDF sampled at `points` evenly spaced quantiles; returns
/// (value, cumulative_fraction) pairs suitable for the paper's CDF plots.
pub fn cdf(xs: &[f64], points: usize) -> Vec<(f64, f64)> {
    if xs.is_empty() {
        return vec![];
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len();
    (1..=points)
        .map(|i| {
            let frac = i as f64 / points as f64;
            let idx = ((frac * n as f64).ceil() as usize).clamp(1, n) - 1;
            (sorted[idx], frac)
        })
        .collect()
}

/// Histogram with `bins` equal-width buckets over [min, max].
pub fn histogram(xs: &[f64], bins: usize) -> Vec<(f64, usize)> {
    if xs.is_empty() || bins == 0 {
        return vec![];
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let width = ((hi - lo) / bins as f64).max(f64::MIN_POSITIVE);
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let b = (((x - lo) / width) as usize).min(bins - 1);
        counts[b] += 1;
    }
    counts
        .into_iter()
        .enumerate()
        .map(|(i, c)| (lo + (i as f64 + 0.5) * width, c))
        .collect()
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(f64::MIN_POSITIVE).ln()).sum::<f64>()
        / xs.len() as f64)
        .exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0)
            .abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn percentile_single_element() {
        assert_eq!(percentile(&[5.0], 99.0), 5.0);
    }

    #[test]
    fn cdf_monotone() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let c = cdf(&xs, 10);
        assert_eq!(c.len(), 10);
        for w in c.windows(2) {
            assert!(w[0].0 <= w[1].0 && w[0].1 < w[1].1);
        }
        assert_eq!(c.last().unwrap().1, 1.0);
    }

    #[test]
    fn histogram_counts_everything() {
        let xs = [0.0, 0.1, 0.5, 0.9, 1.0];
        let h = histogram(&xs, 2);
        assert_eq!(h.iter().map(|(_, c)| c).sum::<usize>(), xs.len());
    }

    #[test]
    fn geomean_of_twos() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }
}
