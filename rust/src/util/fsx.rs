//! Filesystem helpers for CLI output paths.
//!
//! Report writers (`--out`, `--telemetry`, `--telemetry-dir`) share two
//! requirements: missing parent directories are created instead of
//! failing, and failures surface as a one-line message naming the path —
//! not a raw `io::Error` panic with no context.

use std::path::Path;

/// Write `contents` to `path`, creating any missing parent directories.
/// Errors carry the offending path and the underlying OS message.
pub fn write_creating(path: &Path, contents: &[u8]) -> Result<(), String> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).map_err(|e| {
                format!(
                    "cannot create directory {}: {}",
                    parent.display(),
                    e
                )
            })?;
        }
    }
    std::fs::write(path, contents)
        .map_err(|e| format!("cannot write {}: {}", path.display(), e))
}

/// Ensure `dir` exists (creating the whole chain), with the same
/// path-naming error contract as [`write_creating`].
pub fn ensure_dir(dir: &Path) -> Result<(), String> {
    std::fs::create_dir_all(dir).map_err(|e| {
        format!("cannot create directory {}: {}", dir.display(), e)
    })
}

/// CLI surface: [`write_creating`] or exit(2) with a one-line error
/// naming what was being written.
pub fn write_or_exit(path: &str, contents: &str, what: &str) {
    if let Err(e) = write_creating(Path::new(path), contents.as_bytes()) {
        eprintln!("error: writing {what}: {e}");
        std::process::exit(2);
    }
}

/// Append `bytes` to `path` (creating it and any missing parents) and
/// fsync the file data before returning. Write-ahead-log contract: once
/// this returns `Ok`, the record survives a crash of the process — the
/// caller may acknowledge it.
pub fn append_durable(path: &Path, bytes: &[u8]) -> Result<(), String> {
    use std::io::Write;
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).map_err(|e| {
                format!("cannot create directory {}: {}", parent.display(), e)
            })?;
        }
    }
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .map_err(|e| format!("cannot open {}: {}", path.display(), e))?;
    f.write_all(bytes)
        .map_err(|e| format!("cannot append {}: {}", path.display(), e))?;
    f.sync_data()
        .map_err(|e| format!("cannot fsync {}: {}", path.display(), e))
}

/// fsync a directory so entries created or renamed inside it are
/// durable (segment rotation: create the new segment, then sync its
/// parent so the directory entry itself survives a crash).
pub fn sync_dir(dir: &Path) -> Result<(), String> {
    let f = std::fs::File::open(dir)
        .map_err(|e| format!("cannot open {}: {}", dir.display(), e))?;
    f.sync_all()
        .map_err(|e| format!("cannot fsync {}: {}", dir.display(), e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn scratch(name: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("synergy-fsx-{}-{}", std::process::id(), name))
    }

    #[test]
    fn write_creating_makes_missing_parents() {
        let root = scratch("nested");
        let path = root.join("a/b/report.json");
        write_creating(&path, b"{}").expect("nested write");
        assert_eq!(std::fs::read(&path).unwrap(), b"{}");
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn write_creating_reports_the_path_on_failure() {
        // A file used as a directory component cannot be created.
        let root = scratch("blocked");
        std::fs::create_dir_all(&root).unwrap();
        let file = root.join("plain");
        std::fs::write(&file, b"x").unwrap();
        let err = write_creating(&file.join("sub/report.json"), b"{}")
            .unwrap_err();
        assert!(
            err.contains("cannot create directory")
                && err.contains("plain"),
            "unhelpful error: {err}"
        );
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn bare_filenames_need_no_parent() {
        // `path.parent()` of a bare name is "" — must not try to create
        // it. Write into a scratch dir we cd'd… no: just exercise the
        // empty-parent branch via a relative path in temp.
        let root = scratch("bare");
        std::fs::create_dir_all(&root).unwrap();
        let path = root.join("flat.txt");
        write_creating(&path, b"ok").expect("flat write");
        assert_eq!(std::fs::read(&path).unwrap(), b"ok");
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn append_durable_accumulates_records() {
        let root = scratch("wal");
        let path = root.join("seg/wal-000000.jsonl");
        append_durable(&path, b"a\n").expect("first append");
        append_durable(&path, b"b\n").expect("second append");
        assert_eq!(std::fs::read(&path).unwrap(), b"a\nb\n");
        sync_dir(&path.parent().unwrap()).expect("dir fsync");
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn ensure_dir_is_idempotent() {
        let root = scratch("dir");
        ensure_dir(&root).expect("create");
        ensure_dir(&root).expect("again");
        assert!(root.is_dir());
        std::fs::remove_dir_all(&root).unwrap();
    }
}
