//! Branch-and-bound integer programming on top of the simplex solver.
//!
//! Synergy-OPT's first program is solved as an ILP with boolean selection
//! variables (paper §4.1: "In our experiments, we solve this as a Integer
//! Linear Program"). The LP relaxation of its multiple-choice-knapsack
//! structure has at most two fractional jobs (one per capacity
//! constraint), so branch-and-bound closes the gap in a handful of nodes.

use super::simplex::{solve, Lp, LpError, LpSolution, Op};

/// Options controlling the search.
#[derive(Debug, Clone, Copy)]
pub struct IlpOptions {
    /// Hard cap on explored nodes (safety valve; the Synergy problems
    /// need far fewer).
    pub max_nodes: usize,
    /// Integrality tolerance.
    pub tol: f64,
}

impl Default for IlpOptions {
    fn default() -> Self {
        IlpOptions { max_nodes: 10_000, tol: 1e-6 }
    }
}

/// Solve `lp` with the variables in `int_vars` constrained to integers
/// (binary in the Synergy usage — bounds come from the LP's own
/// constraints). Returns the best integral solution found.
pub fn solve_ilp(
    lp: &Lp,
    int_vars: &[usize],
    opts: IlpOptions,
) -> Result<LpSolution, LpError> {
    let root = solve(lp)?;
    let mut best: Option<LpSolution> = None;
    let mut nodes = 0usize;
    // Stack of (lp, relaxation solution).
    let mut stack: Vec<(Lp, LpSolution)> = vec![(lp.clone(), root)];

    while let Some((node_lp, relax)) = stack.pop() {
        nodes += 1;
        if nodes > opts.max_nodes {
            break;
        }
        // Bound: prune if the relaxation can't beat the incumbent.
        if let Some(ref b) = best {
            if relax.objective <= b.objective + opts.tol {
                continue;
            }
        }
        // Find the most fractional integer variable.
        let mut branch_var = None;
        let mut best_frac = opts.tol;
        for &v in int_vars {
            let frac = (relax.x[v] - relax.x[v].round()).abs();
            if frac > best_frac {
                best_frac = frac;
                branch_var = Some(v);
            }
        }
        match branch_var {
            None => {
                // Integral: candidate incumbent.
                if best
                    .as_ref()
                    .map(|b| relax.objective > b.objective + opts.tol)
                    .unwrap_or(true)
                {
                    best = Some(relax);
                }
            }
            Some(v) => {
                let floor = relax.x[v].floor();
                // Branch x_v <= floor and x_v >= floor + 1; solve children
                // immediately so the stack stores bounded relaxations.
                for (op, rhs) in
                    [(Op::Le, floor), (Op::Ge, floor + 1.0)]
                {
                    let mut child = node_lp.clone();
                    child.add(vec![(v, 1.0)], op, rhs);
                    if let Ok(sol) = solve(&child) {
                        let keep = best
                            .as_ref()
                            .map(|b| sol.objective > b.objective + opts.tol)
                            .unwrap_or(true);
                        if keep {
                            stack.push((child, sol));
                        }
                    }
                }
            }
        }
    }

    best.ok_or(LpError::Infeasible)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knapsack_integral_optimum() {
        // max 3a + 2b + 2c s.t. 2a + b + c <= 2, binary.
        // best: b + c = 2 -> value 4 (beats a alone = 3).
        let mut lp = Lp::new(3);
        lp.set_objective(0, 3.0);
        lp.set_objective(1, 2.0);
        lp.set_objective(2, 2.0);
        lp.add(vec![(0, 2.0), (1, 1.0), (2, 1.0)], Op::Le, 2.0);
        for v in 0..3 {
            lp.add(vec![(v, 1.0)], Op::Le, 1.0);
        }
        let s = solve_ilp(&lp, &[0, 1, 2], IlpOptions::default()).unwrap();
        assert!((s.objective - 4.0).abs() < 1e-6, "obj={}", s.objective);
        assert!(s.x[0].abs() < 1e-6);
    }

    #[test]
    fn multiple_choice_structure() {
        // The Synergy-OPT shape from simplex tests; integral answer is 4.
        let mut lp = Lp::new(4);
        for (i, v) in [1.0, 3.0, 1.0, 2.0].iter().enumerate() {
            lp.set_objective(i, *v);
        }
        lp.add(vec![(0, 1.0), (1, 3.0), (2, 1.0), (3, 3.0)], Op::Le, 4.0);
        lp.add(vec![(0, 1.0), (1, 1.0)], Op::Eq, 1.0);
        lp.add(vec![(2, 1.0), (3, 1.0)], Op::Eq, 1.0);
        let s = solve_ilp(&lp, &[0, 1, 2, 3], IlpOptions::default()).unwrap();
        assert!((s.objective - 4.0).abs() < 1e-6, "obj={}", s.objective);
        for &v in &s.x {
            assert!((v - v.round()).abs() < 1e-6, "fractional {v}");
        }
    }

    #[test]
    fn already_integral_relaxation_short_circuits() {
        let mut lp = Lp::new(1);
        lp.set_objective(0, 1.0);
        lp.add(vec![(0, 1.0)], Op::Le, 3.0);
        let s = solve_ilp(&lp, &[0], IlpOptions::default()).unwrap();
        assert!((s.objective - 3.0).abs() < 1e-6);
    }

    #[test]
    fn infeasible_ilp() {
        let mut lp = Lp::new(1);
        lp.set_objective(0, 1.0);
        lp.add(vec![(0, 1.0)], Op::Ge, 2.0);
        lp.add(vec![(0, 1.0)], Op::Le, 1.0);
        assert!(solve_ilp(&lp, &[0], IlpOptions::default()).is_err());
    }

    #[test]
    fn fractional_relaxation_gets_rounded_down_correctly() {
        // max x s.t. 2x <= 3, x integer -> x = 1.
        let mut lp = Lp::new(1);
        lp.set_objective(0, 1.0);
        lp.add(vec![(0, 2.0)], Op::Le, 3.0);
        let s = solve_ilp(&lp, &[0], IlpOptions::default()).unwrap();
        assert!((s.x[0] - 1.0).abs() < 1e-6);
    }
}
