//! Linear programming substrate for Synergy-OPT (paper §4.1, Appendix A).
//!
//! The paper solves its upper-bound formulation with CVXPY; no external
//! solver exists in this offline environment, so this module implements:
//!
//! - [`simplex`] — a dense two-phase tableau simplex with Bland's rule
//!   (max c·x subject to Ax {≤,=,≥} b, x ≥ 0);
//! - [`ilp`] — branch-and-bound on top of it for integer variables
//!   (Synergy-OPT's `y_{c,m,j}` selection variables are boolean).
//!
//! The Synergy-OPT LP builders themselves live in
//! [`crate::mechanism::opt`]; this module is problem-agnostic.

pub mod ilp;
pub mod simplex;

pub use ilp::{solve_ilp, IlpOptions};
pub use simplex::{solve, Constraint, Lp, LpError, LpSolution, Op};
