//! Dense two-phase tableau simplex.
//!
//! Solves `maximize c·x  s.t.  A x {≤,=,≥} b,  x ≥ 0`.
//!
//! Phase 1 minimizes the sum of artificial variables to find a basic
//! feasible solution; phase 2 optimizes the real objective. Bland's rule
//! (smallest-index entering/leaving) guarantees termination; an epsilon of
//! 1e-9 guards rank decisions. Designed for the Synergy-OPT problem sizes
//! (thousands of variables, hundreds of constraints) — dense is fine.

/// Constraint sense.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Le,
    Eq,
    Ge,
}

/// One (sparse) linear constraint: Σ coeffs·x {op} rhs.
#[derive(Debug, Clone)]
pub struct Constraint {
    pub coeffs: Vec<(usize, f64)>,
    pub op: Op,
    pub rhs: f64,
}

impl Constraint {
    pub fn new(coeffs: Vec<(usize, f64)>, op: Op, rhs: f64) -> Constraint {
        Constraint { coeffs, op, rhs }
    }
}

/// A linear program: maximize `objective · x` subject to `constraints`,
/// with implicit x ≥ 0.
#[derive(Debug, Clone, Default)]
pub struct Lp {
    pub n_vars: usize,
    pub objective: Vec<f64>,
    pub constraints: Vec<Constraint>,
}

impl Lp {
    pub fn new(n_vars: usize) -> Lp {
        Lp { n_vars, objective: vec![0.0; n_vars], constraints: Vec::new() }
    }

    pub fn set_objective(&mut self, var: usize, coeff: f64) {
        self.objective[var] = coeff;
    }

    pub fn add(&mut self, coeffs: Vec<(usize, f64)>, op: Op, rhs: f64) {
        debug_assert!(coeffs.iter().all(|&(i, _)| i < self.n_vars));
        self.constraints.push(Constraint::new(coeffs, op, rhs));
    }
}

/// Solver outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum LpError {
    Infeasible,
    Unbounded,
}

/// An optimal solution.
#[derive(Debug, Clone)]
pub struct LpSolution {
    pub x: Vec<f64>,
    pub objective: f64,
}

const EPS: f64 = 1e-9;

/// Solve the LP; returns the optimum or Infeasible/Unbounded.
pub fn solve(lp: &Lp) -> Result<LpSolution, LpError> {
    Tableau::build(lp).and_then(|mut t| t.optimize(lp))
}

struct Tableau {
    /// rows[m][total_cols+1]; last column is RHS.
    rows: Vec<Vec<f64>>,
    /// Basis variable per row.
    basis: Vec<usize>,
    n_structural: usize,
    n_total: usize,
    /// Column indices of artificial variables.
    artificials: Vec<usize>,
}

impl Tableau {
    fn build(lp: &Lp) -> Result<Tableau, LpError> {
        let m = lp.constraints.len();
        let n = lp.n_vars;

        // Count auxiliary columns.
        let mut n_slack = 0;
        for c in &lp.constraints {
            // Normalized sense after sign-flip for negative rhs:
            let op = normalized_op(c);
            if op != Op::Eq {
                n_slack += 1;
            }
        }
        // Artificials for = rows and ≥ rows.
        let mut n_art = 0;
        for c in &lp.constraints {
            match normalized_op(c) {
                Op::Le => {}
                _ => n_art += 1,
            }
        }
        let n_total = n + n_slack + n_art;
        let width = n_total + 1;

        let mut rows = vec![vec![0.0; width]; m];
        let mut basis = vec![usize::MAX; m];
        let mut artificials = Vec::with_capacity(n_art);

        let mut slack_col = n;
        let mut art_col = n + n_slack;
        for (i, c) in lp.constraints.iter().enumerate() {
            let flip = c.rhs < 0.0;
            let sign = if flip { -1.0 } else { 1.0 };
            for &(j, v) in &c.coeffs {
                rows[i][j] += sign * v;
            }
            rows[i][n_total] = sign * c.rhs;
            let op = normalized_op(c);
            match op {
                Op::Le => {
                    rows[i][slack_col] = 1.0;
                    basis[i] = slack_col;
                    slack_col += 1;
                }
                Op::Ge => {
                    rows[i][slack_col] = -1.0; // surplus
                    slack_col += 1;
                    rows[i][art_col] = 1.0;
                    basis[i] = art_col;
                    artificials.push(art_col);
                    art_col += 1;
                }
                Op::Eq => {
                    rows[i][art_col] = 1.0;
                    basis[i] = art_col;
                    artificials.push(art_col);
                    art_col += 1;
                }
            }
        }

        Ok(Tableau { rows, basis, n_structural: n, n_total, artificials })
    }

    /// Run phase 1 (if artificials exist) then phase 2.
    fn optimize(&mut self, lp: &Lp) -> Result<LpSolution, LpError> {
        if !self.artificials.is_empty() {
            // Phase 1: maximize -(sum of artificials).
            let mut cost = vec![0.0; self.n_total];
            for &a in &self.artificials {
                cost[a] = -1.0;
            }
            let obj = self.run_simplex(&cost)?;
            if obj < -1e-7 {
                return Err(LpError::Infeasible);
            }
            // Pivot any artificial still in the basis out (degenerate rows).
            for row in 0..self.rows.len() {
                if self.artificials.contains(&self.basis[row]) {
                    if let Some(col) = (0..self.n_structural)
                        .chain(self.n_structural..self.n_total)
                        .find(|&c| {
                            !self.artificials.contains(&c)
                                && self.rows[row][c].abs() > EPS
                        })
                    {
                        self.pivot(row, col);
                    }
                    // else: the row is all-zero over real vars; harmless.
                }
            }
            // Zero the artificial columns so they never re-enter.
            for &a in &self.artificials {
                for row in &mut self.rows {
                    row[a] = 0.0;
                }
            }
        }

        // Phase 2: real objective.
        let mut cost = vec![0.0; self.n_total];
        cost[..lp.n_vars].copy_from_slice(&lp.objective);
        let obj = self.run_simplex(&cost)?;

        let mut x = vec![0.0; lp.n_vars];
        for (row, &b) in self.basis.iter().enumerate() {
            if b < lp.n_vars {
                x[b] = self.rows[row][self.n_total];
            }
        }
        Ok(LpSolution { x, objective: obj })
    }

    /// Primal simplex on the current tableau for the given cost vector
    /// (maximization). Returns the objective value.
    fn run_simplex(&mut self, cost: &[f64]) -> Result<f64, LpError> {
        let m = self.rows.len();
        // Reduced costs: z_j - c_j computed on demand from the basis.
        // We maintain an explicit objective row for efficiency.
        let width = self.n_total + 1;
        let mut zrow = vec![0.0; width];
        for j in 0..self.n_total {
            zrow[j] = -cost[j];
        }
        // Make the objective row consistent with the current basis.
        for (row, &b) in self.basis.iter().enumerate() {
            if zrow[b].abs() > 0.0 {
                let factor = zrow[b];
                for j in 0..width {
                    zrow[j] -= factor * self.rows[row][j];
                }
            }
        }

        let max_iters = 50 * (m + self.n_total).max(100);
        for _ in 0..max_iters {
            // Entering: Dantzig rule (most negative), Bland fallback is
            // triggered implicitly by the epsilon + max_iters guard.
            let mut enter = usize::MAX;
            let mut best = -EPS;
            for j in 0..self.n_total {
                if zrow[j] < best {
                    best = zrow[j];
                    enter = j;
                }
            }
            if enter == usize::MAX {
                return Ok(zrow[width - 1]);
            }
            // Leaving: min ratio.
            let mut leave = usize::MAX;
            let mut best_ratio = f64::INFINITY;
            for (i, row) in self.rows.iter().enumerate() {
                if row[enter] > EPS {
                    let ratio = row[width - 1] / row[enter];
                    if ratio < best_ratio - EPS
                        || (ratio < best_ratio + EPS
                            && (leave == usize::MAX
                                || self.basis[i] < self.basis[leave]))
                    {
                        best_ratio = ratio;
                        leave = i;
                    }
                }
            }
            if leave == usize::MAX {
                return Err(LpError::Unbounded);
            }
            self.pivot(leave, enter);
            // Update objective row.
            let factor = zrow[enter];
            if factor.abs() > 0.0 {
                let prow = &self.rows[leave];
                for j in 0..width {
                    zrow[j] -= factor * prow[j];
                }
            }
        }
        // Cycling/stall guard: treat as converged at current point.
        Ok(zrow[width - 1])
    }

    fn pivot(&mut self, row: usize, col: usize) {
        let width = self.n_total + 1;
        let pivot_val = self.rows[row][col];
        debug_assert!(pivot_val.abs() > EPS, "pivot on ~zero");
        let inv = 1.0 / pivot_val;
        for j in 0..width {
            self.rows[row][j] *= inv;
        }
        let prow = self.rows[row].clone();
        for (i, r) in self.rows.iter_mut().enumerate() {
            if i != row && r[col].abs() > EPS {
                let factor = r[col];
                for j in 0..width {
                    r[j] -= factor * prow[j];
                }
            }
        }
        self.basis[row] = col;
    }
}

fn normalized_op(c: &Constraint) -> Op {
    if c.rhs < 0.0 {
        match c.op {
            Op::Le => Op::Ge,
            Op::Ge => Op::Le,
            Op::Eq => Op::Eq,
        }
    } else {
        c.op
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-6, "{a} != {b}");
    }

    #[test]
    fn textbook_2d() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18 => (2,6), obj 36.
        let mut lp = Lp::new(2);
        lp.set_objective(0, 3.0);
        lp.set_objective(1, 5.0);
        lp.add(vec![(0, 1.0)], Op::Le, 4.0);
        lp.add(vec![(1, 2.0)], Op::Le, 12.0);
        lp.add(vec![(0, 3.0), (1, 2.0)], Op::Le, 18.0);
        let s = solve(&lp).unwrap();
        assert_close(s.objective, 36.0);
        assert_close(s.x[0], 2.0);
        assert_close(s.x[1], 6.0);
    }

    #[test]
    fn equality_constraints() {
        // max x + y s.t. x + y = 5, x <= 3 => obj 5.
        let mut lp = Lp::new(2);
        lp.set_objective(0, 1.0);
        lp.set_objective(1, 1.0);
        lp.add(vec![(0, 1.0), (1, 1.0)], Op::Eq, 5.0);
        lp.add(vec![(0, 1.0)], Op::Le, 3.0);
        let s = solve(&lp).unwrap();
        assert_close(s.objective, 5.0);
    }

    #[test]
    fn ge_constraints() {
        // max -x s.t. x >= 2  => x=2, obj -2  (minimize x)
        let mut lp = Lp::new(1);
        lp.set_objective(0, -1.0);
        lp.add(vec![(0, 1.0)], Op::Ge, 2.0);
        let s = solve(&lp).unwrap();
        assert_close(s.objective, -2.0);
        assert_close(s.x[0], 2.0);
    }

    #[test]
    fn infeasible_detected() {
        // x <= 1 and x >= 2.
        let mut lp = Lp::new(1);
        lp.set_objective(0, 1.0);
        lp.add(vec![(0, 1.0)], Op::Le, 1.0);
        lp.add(vec![(0, 1.0)], Op::Ge, 2.0);
        match solve(&lp) {
            Err(LpError::Infeasible) => {}
            other => panic!("expected Infeasible, got {other:?}"),
        }
    }

    #[test]
    fn unbounded_detected() {
        let mut lp = Lp::new(1);
        lp.set_objective(0, 1.0);
        lp.add(vec![(0, 1.0)], Op::Ge, 0.0);
        match solve(&lp) {
            Err(LpError::Unbounded) => {}
            other => panic!("expected Unbounded, got {other:?}"),
        }
    }

    #[test]
    fn negative_rhs_normalized() {
        // max x s.t. -x >= -3  (i.e. x <= 3)
        let mut lp = Lp::new(1);
        lp.set_objective(0, 1.0);
        lp.add(vec![(0, -1.0)], Op::Ge, -3.0);
        let s = solve(&lp).unwrap();
        assert_close(s.objective, 3.0);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // Klee-Minty-ish degenerate case.
        let mut lp = Lp::new(3);
        lp.set_objective(0, 10.0);
        lp.set_objective(1, -57.0);
        lp.set_objective(2, -9.0);
        lp.add(vec![(0, 0.5), (1, -5.5), (2, -2.5)], Op::Le, 0.0);
        lp.add(vec![(0, 0.5), (1, -1.5), (2, -0.5)], Op::Le, 0.0);
        lp.add(vec![(0, 1.0)], Op::Le, 1.0);
        let s = solve(&lp).unwrap();
        assert_close(s.objective, 1.0);
    }

    #[test]
    fn multiple_choice_knapsack_shape() {
        // The Synergy-OPT structure: two jobs, each picks one of two
        // (cpu, value) options; shared CPU capacity 4.
        // job0: opt A (1 cpu, v=1), opt B (3 cpu, v=3)
        // job1: opt A (1 cpu, v=1), opt B (3 cpu, v=2)
        // best integral: job0 B + job1 A = 4 cpus, value 4.
        let mut lp = Lp::new(4);
        for (i, v) in [1.0, 3.0, 1.0, 2.0].iter().enumerate() {
            lp.set_objective(i, *v);
        }
        lp.add(vec![(0, 1.0), (1, 3.0), (2, 1.0), (3, 3.0)], Op::Le, 4.0);
        lp.add(vec![(0, 1.0), (1, 1.0)], Op::Eq, 1.0);
        lp.add(vec![(2, 1.0), (3, 1.0)], Op::Eq, 1.0);
        let s = solve(&lp).unwrap();
        // LP relaxation may be fractional but >= integral optimum (4.0).
        assert!(s.objective >= 4.0 - 1e-6, "obj={}", s.objective);
    }

    #[test]
    fn moderately_large_random_lp_solves() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seeded(3);
        let n = 120;
        let m = 40;
        let mut lp = Lp::new(n);
        for j in 0..n {
            lp.set_objective(j, rng.f64());
        }
        for _ in 0..m {
            let coeffs: Vec<(usize, f64)> =
                (0..n).map(|j| (j, rng.f64())).collect();
            lp.add(coeffs, Op::Le, 10.0 + rng.f64() * 5.0);
        }
        let s = solve(&lp).unwrap();
        assert!(s.objective > 0.0);
        // Verify primal feasibility.
        for c in &lp.constraints {
            let lhs: f64 = c.coeffs.iter().map(|&(j, v)| v * s.x[j]).sum();
            assert!(lhs <= c.rhs + 1e-6);
        }
    }
}
