//! Scheduling policies (paper §2.2): the *policy* decides which jobs run
//! in a round; the *mechanism* ([`crate::mechanism`]) decides where and
//! with how many fungible resources.
//!
//! Implemented: FIFO, SRTF, LAS (Tiresias-style), FTF (Themis-style), plus
//! the big-data baselines DRF and Tetris used in §5.7. All are expressed
//! as priority orderings over a job view; round-based preemption comes
//! from the coordinator re-evaluating the ordering every round.

use crate::job::JobId;

/// The per-job facts a policy may rank on.
#[derive(Debug, Clone, Copy)]
pub struct PolicyJobView {
    pub id: JobId,
    pub arrival_s: f64,
    /// Total GPU-seconds of service received so far (LAS).
    pub attained_service_s: f64,
    /// Estimated remaining runtime at GPU-proportional throughput (SRTF).
    pub remaining_est_s: f64,
    /// Baseline duration under GPU-proportional allocation (FTF).
    pub duration_prop_s: f64,
    pub gpus: u32,
    /// Best-case demand share of the dominant resource (DRF), in [0,1].
    pub dominant_share: f64,
    /// Tetris alignment score of the job's demand with cluster free
    /// resources (higher packs better).
    pub alignment: f64,
}

/// A scheduling policy: a total priority order over runnable jobs.
pub trait SchedulingPolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Sort key: *lower* sorts first (higher priority). Ties broken by
    /// arrival time then id for determinism.
    fn key(&self, job: &PolicyJobView, now: f64) -> f64;

    /// Order jobs by priority (highest priority first).
    ///
    /// NaN keys (e.g. an SRTF remaining-time estimate poisoned by a 0/0
    /// throughput) are normalized to `+inf` so they deterministically
    /// sort last instead of panicking mid-round. The normalization
    /// matters: 0/0 yields a *sign-negative* NaN on x86-64, which a bare
    /// `total_cmp` would sort ahead of every valid key.
    fn order(&self, jobs: &mut Vec<PolicyJobView>, now: f64) {
        fn sane(k: f64) -> f64 {
            if k.is_nan() {
                f64::INFINITY
            } else {
                k
            }
        }
        jobs.sort_by(|a, b| {
            sane(self.key(a, now))
                .total_cmp(&sane(self.key(b, now)))
                .then(a.arrival_s.total_cmp(&b.arrival_s))
                .then(a.id.cmp(&b.id))
        });
    }
}

/// First-In-First-Out: priority = arrival time.
pub struct Fifo;

impl SchedulingPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }
    fn key(&self, job: &PolicyJobView, _now: f64) -> f64 {
        job.arrival_s
    }
}

/// Shortest-Remaining-Time-First.
pub struct Srtf;

impl SchedulingPolicy for Srtf {
    fn name(&self) -> &'static str {
        "srtf"
    }
    fn key(&self, job: &PolicyJobView, _now: f64) -> f64 {
        job.remaining_est_s
    }
}

/// Least-Attained-Service (Tiresias): priority = GPU-seconds received.
pub struct Las;

impl SchedulingPolicy for Las {
    fn name(&self) -> &'static str {
        "las"
    }
    fn key(&self, job: &PolicyJobView, _now: f64) -> f64 {
        job.attained_service_s * job.gpus as f64
    }
}

/// Finish-Time-Fairness (Themis): schedule the job whose projected
/// sharing penalty ρ = (elapsed + remaining) / ideal-duration is largest.
pub struct Ftf;

impl SchedulingPolicy for Ftf {
    fn name(&self) -> &'static str {
        "ftf"
    }
    fn key(&self, job: &PolicyJobView, now: f64) -> f64 {
        let elapsed = (now - job.arrival_s).max(0.0);
        let rho = (elapsed + job.remaining_est_s)
            / job.duration_prop_s.max(1e-9);
        -rho // largest ρ first
    }
}

/// Dominant-Resource-Fairness (big-data baseline, §5.7): progressive
/// filling — always serve the job with the smallest dominant share.
pub struct Drf;

impl SchedulingPolicy for Drf {
    fn name(&self) -> &'static str {
        "drf"
    }
    fn key(&self, job: &PolicyJobView, _now: f64) -> f64 {
        job.dominant_share
    }
}

/// Tetris (big-data baseline, §5.7): pack jobs whose demand vector aligns
/// best with the free-resource vector first.
pub struct Tetris;

impl SchedulingPolicy for Tetris {
    fn name(&self) -> &'static str {
        "tetris"
    }
    fn key(&self, job: &PolicyJobView, _now: f64) -> f64 {
        -job.alignment // highest alignment first
    }
}

/// Look up a policy by CLI name.
pub fn by_name(name: &str) -> Option<Box<dyn SchedulingPolicy>> {
    match name {
        "fifo" => Some(Box::new(Fifo)),
        "srtf" => Some(Box::new(Srtf)),
        "las" => Some(Box::new(Las)),
        "ftf" => Some(Box::new(Ftf)),
        "drf" => Some(Box::new(Drf)),
        "tetris" => Some(Box::new(Tetris)),
        _ => None,
    }
}

/// All policy names (for CLI help and sweeps).
pub const ALL_POLICIES: [&str; 6] = ["fifo", "srtf", "las", "ftf", "drf", "tetris"];

#[cfg(test)]
mod tests {
    use super::*;

    fn view(id: u64) -> PolicyJobView {
        PolicyJobView {
            id: JobId(id),
            arrival_s: id as f64,
            attained_service_s: 0.0,
            remaining_est_s: 100.0,
            duration_prop_s: 100.0,
            gpus: 1,
            dominant_share: 0.1,
            alignment: 0.0,
        }
    }

    #[test]
    fn fifo_orders_by_arrival() {
        let mut jobs = vec![view(2), view(0), view(1)];
        Fifo.order(&mut jobs, 10.0);
        let ids: Vec<u64> = jobs.iter().map(|j| j.id.0).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn srtf_prefers_short_jobs() {
        let mut a = view(0);
        a.remaining_est_s = 500.0;
        let mut b = view(1);
        b.remaining_est_s = 50.0;
        let mut jobs = vec![a, b];
        Srtf.order(&mut jobs, 0.0);
        assert_eq!(jobs[0].id, JobId(1));
    }

    #[test]
    fn las_prefers_least_served_weighted_by_gpus() {
        let mut a = view(0);
        a.attained_service_s = 100.0;
        a.gpus = 1;
        let mut b = view(1);
        b.attained_service_s = 60.0;
        b.gpus = 4; // 240 gpu-seconds > 100
        let mut jobs = vec![b, a];
        Las.order(&mut jobs, 0.0);
        assert_eq!(jobs[0].id, JobId(0));
    }

    #[test]
    fn ftf_prefers_most_unfair() {
        let mut a = view(0); // waited long relative to its size
        a.arrival_s = 0.0;
        a.duration_prop_s = 10.0;
        a.remaining_est_s = 10.0;
        let mut b = view(1);
        b.arrival_s = 90.0;
        b.duration_prop_s = 1000.0;
        b.remaining_est_s = 1000.0;
        let mut jobs = vec![b, a];
        Ftf.order(&mut jobs, 100.0);
        assert_eq!(jobs[0].id, JobId(0)); // rho = 110/10 >> (10+1000)/1000
    }

    #[test]
    fn drf_progressive_filling() {
        let mut a = view(0);
        a.dominant_share = 0.5;
        let mut b = view(1);
        b.dominant_share = 0.125;
        let mut jobs = vec![a, b];
        Drf.order(&mut jobs, 0.0);
        assert_eq!(jobs[0].id, JobId(1));
    }

    #[test]
    fn tetris_highest_alignment_first() {
        let mut a = view(0);
        a.alignment = 1.0;
        let mut b = view(1);
        b.alignment = 5.0;
        let mut jobs = vec![a, b];
        Tetris.order(&mut jobs, 0.0);
        assert_eq!(jobs[0].id, JobId(1));
    }

    #[test]
    fn ties_break_deterministically() {
        let mut jobs = vec![view(5), view(3), view(4)];
        for j in &mut jobs {
            j.arrival_s = 0.0;
        }
        Fifo.order(&mut jobs, 0.0);
        let ids: Vec<u64> = jobs.iter().map(|j| j.id.0).collect();
        assert_eq!(ids, vec![3, 4, 5]);
    }

    #[test]
    fn nan_keys_do_not_panic_and_never_outrank_finite_keys() {
        // Regression: `partial_cmp(...).unwrap()` panicked on NaN keys.
        // An SRTF estimate can be NaN when remaining/throughput is 0/0 —
        // and that NaN is *sign-negative* on x86-64, so it must be
        // normalized, not just total_cmp'd (a bare total_cmp would give
        // a poisoned job top priority).
        let neg_nan = 0.0f64 / 0.0f64; // whatever sign the platform gives
        let mut a = view(0);
        a.remaining_est_s = neg_nan;
        let mut b = view(1);
        b.remaining_est_s = 50.0;
        let mut c = view(2);
        c.remaining_est_s = f64::NAN; // positive NaN
        let mut jobs = vec![a, b, c];
        Srtf.order(&mut jobs, 0.0);
        // The finite key always wins; NaN jobs (either sign) rank with
        // +inf and fall back to arrival/id tie-breaks.
        assert_eq!(jobs[0].id, JobId(1));
        assert_eq!(jobs[1].id, JobId(0));
        assert_eq!(jobs[2].id, JobId(2));
        // Re-sorting is stable/deterministic.
        let ids: Vec<u64> = jobs.iter().map(|j| j.id.0).collect();
        Srtf.order(&mut jobs, 0.0);
        assert_eq!(
            jobs.iter().map(|j| j.id.0).collect::<Vec<_>>(),
            ids
        );
    }

    #[test]
    fn by_name_covers_all() {
        for n in ALL_POLICIES {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("nope").is_none());
    }
}
