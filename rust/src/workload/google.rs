//! Google cluster-data (2019, v3) trace reader — the million-job scale
//! ingest path (ROADMAP "Million-job scale").
//!
//! The 2019 Google trace is event-sourced, not row-per-job: a
//! *collection* (≈ job) appears as a sequence of instance events
//! (SUBMIT → SCHEDULE → … → FINISH/KILL), machine capacity is a second
//! event stream, and resource requests are *normalized* to the largest
//! machine (so a separate multiplier record converts them to absolute
//! units). This reader ingests a flat CSV projection of those three
//! pieces:
//!
//! - `instance_events.csv` (required) — columns `time` (μs), `type`
//!   (event code), `collection_id`, `cpus` (normalized request in
//!   \[0,1\]); optional `user` (tenant) and `memory`. Event codes follow
//!   the trace documentation: SUBMIT=0, SCHEDULE=3, EVICT=4, FAIL=5,
//!   FINISH=6, KILL=7; every other code is ignored.
//! - `machine_events.csv` (optional) — `time,machine_id,type` with
//!   ADD=0/REMOVE=1; the net machine count is exposed as a fleet-size
//!   hint ([`GoogleTraceSource::machines`]).
//! - `resource_multipliers.csv` (optional) — one data row whose `cpus`
//!   cell overrides [`cpu_multiplier`]: the normalized→GPU-demand
//!   conversion (`gpus = ceil(cpus_norm × multiplier)`).
//!
//! `--trace` may point at the directory holding those files or directly
//! at an instance-events CSV.
//!
//! **Streaming, bounded memory.** Event rows are consumed line-by-line
//! off a [`BufRead`](std::io::BufRead) — the trace text never
//! materializes. Resident state while parsing is the *open-collections*
//! map (bounded by concurrently live collections, not total jobs) plus
//! the compact emitted rows; a 1M-job trace parses in memory
//! proportional to its concurrency, not its length.
//!
//! Collection lifecycle: SUBMIT opens (re-submits ignored), SCHEDULE
//! stamps the start, EVICT/FAIL clear it (the collection will be
//! re-scheduled; arrival stays at first submit), FINISH emits one job
//! with `duration = finish − schedule`, KILL emits only under
//! [`keep_failed`] (the Philly `status` filter's analogue). Collections
//! that terminate without ever scheduling, or never terminate before
//! EOF, are counted and skipped. Zero/negative-CPU collections are
//! skipped-and-counted *before* tenant interning and model sampling,
//! matching the Philly reader's bit-identity-with-a-pre-filtered-trace
//! semantics. Malformed cells error with their 1-based line number.
//!
//! [`cpu_multiplier`]: GoogleTraceConfig::cpu_multiplier
//! [`keep_failed`]: GoogleTraceConfig::keep_failed

use super::{
    finalize_rows, JobSpec, RawRow, TenantInterner, WorkloadSource,
};
use crate::trace::{Split, SPLIT_DEFAULT};
use crate::util::rng::Pcg64;
use std::collections::BTreeMap;
use std::io::BufRead;

/// Instance-event codes we act on (trace docs table 6); all others are
/// ignored.
const EV_SUBMIT: u32 = 0;
const EV_SCHEDULE: u32 = 3;
const EV_EVICT: u32 = 4;
const EV_FAIL: u32 = 5;
const EV_FINISH: u32 = 6;
const EV_KILL: u32 = 7;

/// Machine-event codes.
const MACH_ADD: u32 = 0;
const MACH_REMOVE: u32 = 1;

/// Reader configuration (see module docs for knob semantics).
#[derive(Debug, Clone)]
pub struct GoogleTraceConfig {
    /// Trace directory (`instance_events.csv` + optional
    /// `machine_events.csv`/`resource_multipliers.csv`) or a single
    /// instance-events CSV file.
    pub path: String,
    /// λ rescale: all inter-arrival gaps are divided by this. Must be
    /// positive.
    pub load_scale: f64,
    /// Normalized-CPU → GPU-demand conversion
    /// (`gpus = ceil(cpus_norm × multiplier)`); overridden by a
    /// `resource_multipliers.csv` row when present. Must be positive.
    pub cpu_multiplier: f64,
    /// GPU-demand remap: demands above this are clamped down (0 disables).
    pub gpu_cap: u32,
    /// Keep only the first N emitted jobs (trace event order).
    pub max_jobs: Option<usize>,
    /// Model mix (the trace carries no model column; every job samples).
    pub split: Split,
    /// Seed for model sampling.
    pub seed: u64,
    /// Also emit KILLed collections (the `status != Pass` analogue).
    pub keep_failed: bool,
    /// Duration clamp, seconds.
    pub duration_min_s: f64,
    pub duration_max_s: f64,
}

impl Default for GoogleTraceConfig {
    fn default() -> Self {
        GoogleTraceConfig {
            path: String::new(),
            load_scale: 1.0,
            cpu_multiplier: 8.0,
            gpu_cap: 16,
            max_jobs: None,
            split: SPLIT_DEFAULT,
            seed: 1,
            keep_failed: false,
            duration_min_s: 1.0,
            duration_max_s: f64::INFINITY,
        }
    }
}

/// One open collection while streaming the event file.
struct Pending {
    submit_us: f64,
    user: String,
    cpus_norm: f64,
    schedule_us: Option<f64>,
}

/// Header-indexed cells of one streamed CSV line (the streaming
/// counterpart of [`super::CsvDoc`], which borrows the whole text).
struct LineCols {
    idx: BTreeMap<&'static str, usize>,
}

impl LineCols {
    fn parse_header(
        header: &str,
        required: &[&'static str],
        optional: &[&'static str],
    ) -> Result<LineCols, String> {
        let cols: Vec<&str> = header.split(',').map(str::trim).collect();
        let mut idx = BTreeMap::new();
        for &name in required.iter().chain(optional) {
            if let Some(i) = cols.iter().position(|c| *c == name) {
                idx.insert(name, i);
            } else if required.contains(&name) {
                return Err(format!("missing column '{name}'"));
            }
        }
        Ok(LineCols { idx })
    }

    fn cell<'l>(
        &self,
        cells: &[&'l str],
        name: &str,
        line_no: usize,
    ) -> Result<Option<&'l str>, String> {
        match self.idx.get(name) {
            None => Ok(None),
            Some(&i) => cells.get(i).copied().map(Some).ok_or_else(|| {
                format!("line {line_no}: too few columns")
            }),
        }
    }

    fn parse<T: std::str::FromStr>(
        &self,
        cells: &[&str],
        name: &str,
        line_no: usize,
    ) -> Result<T, String> {
        self.cell(cells, name, line_no)?
            .ok_or_else(|| format!("line {line_no}: missing {name}"))?
            .parse()
            .map_err(|_| format!("line {line_no}: bad {name}"))
    }
}

/// Yield `(1-based line number, trimmed content)` for data lines,
/// skipping blanks and `#` comments. The first yielded line is the
/// header.
fn data_lines<I>(
    lines: I,
) -> impl Iterator<Item = Result<(usize, String), String>>
where
    I: Iterator<Item = std::io::Result<String>>,
{
    lines.enumerate().filter_map(|(i, l)| match l {
        Err(e) => Some(Err(format!("line {}: read error: {e}", i + 1))),
        Ok(l) => {
            let t = l.trim();
            if t.is_empty() || t.starts_with('#') {
                None
            } else {
                Some(Ok((i + 1, t.to_string())))
            }
        }
    })
}

/// A parsed Google-format trace, streamed in arrival order.
pub struct GoogleTraceSource {
    specs: std::vec::IntoIter<JobSpec>,
    tenant_names: Vec<String>,
    skipped_zero_gpu: usize,
    skipped_unscheduled: usize,
    skipped_incomplete: usize,
    machines: Option<usize>,
}

impl GoogleTraceSource {
    /// Read and parse `cfg.path` (directory or instance-events file).
    /// Errors carry the offending file's line number.
    pub fn new(cfg: GoogleTraceConfig) -> Result<GoogleTraceSource, String> {
        validate(&cfg)?;
        let is_dir = std::fs::metadata(&cfg.path)
            .map(|m| m.is_dir())
            .unwrap_or(false);
        let instance_path = if is_dir {
            format!("{}/instance_events.csv", cfg.path)
        } else {
            cfg.path.clone()
        };

        let mut multiplier = cfg.cpu_multiplier;
        let mut machines = None;
        if is_dir {
            let mult_path = format!("{}/resource_multipliers.csv", cfg.path);
            if let Ok(text) = std::fs::read_to_string(&mult_path) {
                multiplier = parse_multipliers(&text)
                    .map_err(|e| format!("{mult_path}: {e}"))?;
            }
            let mach_path = format!("{}/machine_events.csv", cfg.path);
            if let Ok(f) = std::fs::File::open(&mach_path) {
                let reader = std::io::BufReader::new(f);
                machines = Some(
                    parse_machines(reader.lines())
                        .map_err(|e| format!("{mach_path}: {e}"))?,
                );
            }
        }

        let f = std::fs::File::open(&instance_path)
            .map_err(|e| format!("read {instance_path}: {e}"))?;
        let reader = std::io::BufReader::new(f);
        let mut src = parse_instances(reader.lines(), multiplier, &cfg)
            .map_err(|e| format!("{instance_path}: {e}"))?;
        src.machines = machines;
        src.report_skips(&cfg.path);
        Ok(src)
    }

    /// Parse instance events from an in-memory document (tests/benches);
    /// no multiplier/machine files are consulted.
    pub fn from_str(
        text: &str,
        cfg: &GoogleTraceConfig,
    ) -> Result<GoogleTraceSource, String> {
        validate(cfg)?;
        parse_instances(
            text.lines().map(|l| Ok(l.to_string())),
            cfg.cpu_multiplier,
            cfg,
        )
    }

    /// Parse all three in-memory documents (tests).
    pub fn from_parts(
        instance: &str,
        machines: Option<&str>,
        multipliers: Option<&str>,
        cfg: &GoogleTraceConfig,
    ) -> Result<GoogleTraceSource, String> {
        validate(cfg)?;
        let multiplier = match multipliers {
            Some(text) => parse_multipliers(text)?,
            None => cfg.cpu_multiplier,
        };
        let mach = match machines {
            Some(text) => Some(parse_machines(
                text.lines().map(|l| Ok(l.to_string())),
            )?),
            None => None,
        };
        let mut src = parse_instances(
            instance.lines().map(|l| Ok(l.to_string())),
            multiplier,
            cfg,
        )?;
        src.machines = mach;
        Ok(src)
    }

    /// Collections dropped because their normalized CPU request was ≤ 0
    /// (nothing to gang-schedule).
    pub fn skipped_zero_gpu(&self) -> usize {
        self.skipped_zero_gpu
    }

    /// Collections that reached a terminal event without ever being
    /// scheduled (no running interval to derive a duration from).
    pub fn skipped_unscheduled(&self) -> usize {
        self.skipped_unscheduled
    }

    /// Collections still open at end of trace (no terminal event).
    pub fn skipped_incomplete(&self) -> usize {
        self.skipped_incomplete
    }

    /// Net machine count from `machine_events.csv`, when present — a
    /// fleet-size hint for the caller.
    pub fn machines(&self) -> Option<usize> {
        self.machines
    }

    fn report_skips(&self, path: &str) {
        let total = self.skipped_zero_gpu
            + self.skipped_unscheduled
            + self.skipped_incomplete;
        if total > 0 {
            eprintln!(
                "google trace {path}: skipped {} zero-GPU, {} unscheduled, \
                 {} incomplete collection(s)",
                self.skipped_zero_gpu,
                self.skipped_unscheduled,
                self.skipped_incomplete,
            );
        }
    }
}

fn validate(cfg: &GoogleTraceConfig) -> Result<(), String> {
    if !(cfg.load_scale > 0.0) {
        return Err("load_scale must be positive".to_string());
    }
    if !(cfg.cpu_multiplier > 0.0) {
        return Err("cpu_multiplier must be positive".to_string());
    }
    if !(cfg.duration_min_s <= cfg.duration_max_s) {
        return Err("duration clamp: min > max".to_string());
    }
    Ok(())
}

/// The `resource_multipliers.csv` projection: a header with `cpus`
/// (optionally `memory`) and one data row; the `cpus` value is the
/// normalized→absolute conversion.
fn parse_multipliers(text: &str) -> Result<f64, String> {
    let mut lines = data_lines(text.lines().map(|l| Ok(l.to_string())));
    let (_, header) = lines
        .next()
        .ok_or_else(|| "empty multipliers file".to_string())??;
    let cols = LineCols::parse_header(&header, &["cpus"], &["memory"])?;
    let (line_no, row) = lines
        .next()
        .ok_or_else(|| "multipliers file has no data row".to_string())??;
    let cells: Vec<&str> = row.split(',').map(str::trim).collect();
    let mult: f64 = cols.parse(&cells, "cpus", line_no)?;
    if !(mult.is_finite() && mult > 0.0) {
        return Err(format!("line {line_no}: cpus multiplier must be positive"));
    }
    Ok(mult)
}

/// Stream `machine_events.csv`: net machine count after ADD/REMOVE
/// replay (other codes — e.g. UPDATE — are ignored).
fn parse_machines<I>(lines: I) -> Result<usize, String>
where
    I: Iterator<Item = std::io::Result<String>>,
{
    let mut lines = data_lines(lines);
    let (_, header) = lines
        .next()
        .ok_or_else(|| "empty machine events file".to_string())??;
    let cols = LineCols::parse_header(
        &header,
        &["time", "machine_id", "type"],
        &["cpus", "memory"],
    )?;
    let mut count = 0usize;
    for line in lines {
        let (line_no, row) = line?;
        let cells: Vec<&str> = row.split(',').map(str::trim).collect();
        let _time: f64 = cols.parse(&cells, "time", line_no)?;
        let _id: u64 = cols.parse(&cells, "machine_id", line_no)?;
        let ev: u32 = cols.parse(&cells, "type", line_no)?;
        match ev {
            MACH_ADD => count += 1,
            MACH_REMOVE => count = count.saturating_sub(1),
            _ => {}
        }
    }
    Ok(count)
}

/// Stream the instance-event lines into emitted jobs. `multiplier` is
/// the resolved normalized-CPU → GPU conversion.
fn parse_instances<I>(
    lines: I,
    multiplier: f64,
    cfg: &GoogleTraceConfig,
) -> Result<GoogleTraceSource, String>
where
    I: Iterator<Item = std::io::Result<String>>,
{
    let mut lines = data_lines(lines);
    let (_, header) = lines
        .next()
        .ok_or_else(|| "empty trace file".to_string())??;
    let cols = LineCols::parse_header(
        &header,
        &["time", "type", "collection_id", "cpus"],
        &["user", "memory"],
    )?;

    let mut rng = Pcg64::new(cfg.seed, 0x9B177);
    let mut interner = TenantInterner::new();
    // Open collections: bounded by *concurrent* collections, not trace
    // length — the streaming-memory invariant.
    let mut open: BTreeMap<u64, Pending> = BTreeMap::new();
    let mut rows: Vec<RawRow> = Vec::new();
    let mut skipped_zero_gpu = 0usize;
    let mut skipped_unscheduled = 0usize;

    'stream: for line in lines {
        let (line_no, row) = line?;
        let cells: Vec<&str> = row.split(',').map(str::trim).collect();
        let time_us: f64 = cols.parse(&cells, "time", line_no)?;
        let ev: u32 = cols.parse(&cells, "type", line_no)?;
        let cid: u64 = cols.parse(&cells, "collection_id", line_no)?;
        match ev {
            EV_SUBMIT => {
                let cpus_norm: f64 = cols.parse(&cells, "cpus", line_no)?;
                let user = cols
                    .cell(&cells, "user", line_no)?
                    .filter(|u| !u.is_empty())
                    .unwrap_or("default")
                    .to_string();
                // Re-submits after eviction keep the first arrival.
                open.entry(cid).or_insert(Pending {
                    submit_us: time_us,
                    user,
                    cpus_norm,
                    schedule_us: None,
                });
            }
            EV_SCHEDULE => {
                if let Some(p) = open.get_mut(&cid) {
                    if p.schedule_us.is_none() {
                        p.schedule_us = Some(time_us);
                    }
                }
            }
            EV_EVICT | EV_FAIL => {
                // Back to pending; arrival (first submit) is kept.
                if let Some(p) = open.get_mut(&cid) {
                    p.schedule_us = None;
                }
            }
            EV_FINISH | EV_KILL => {
                let Some(p) = open.remove(&cid) else { continue };
                if ev == EV_KILL && !cfg.keep_failed {
                    // The Philly `status != Pass` filter's analogue:
                    // dropped silently, before any skip counting.
                    continue;
                }
                let Some(sched_us) = p.schedule_us else {
                    skipped_unscheduled += 1;
                    continue;
                };
                if p.cpus_norm <= 0.0 || !p.cpus_norm.is_finite() {
                    // Nothing to gang-schedule; count-and-skip before
                    // interning or model sampling so kept rows are
                    // byte-identical to a pre-filtered trace.
                    skipped_zero_gpu += 1;
                    continue;
                }
                let duration_s = (time_us - sched_us) / 1e6;
                if duration_s < 0.0 {
                    return Err(format!(
                        "line {line_no}: collection {cid} finishes before \
                         its schedule time"
                    ));
                }
                let tenant = interner.intern(&p.user);
                let model = cfg.split.sample_model(&mut rng);
                let gpus_raw = (p.cpus_norm * multiplier).ceil() as u32;
                let gpus_raw = gpus_raw.max(1);
                let gpus = if cfg.gpu_cap > 0 {
                    gpus_raw.min(cfg.gpu_cap)
                } else {
                    gpus_raw
                };
                let duration_s = duration_s
                    .clamp(cfg.duration_min_s, cfg.duration_max_s);
                rows.push((p.submit_us / 1e6, tenant, model, gpus, duration_s));
                if let Some(max) = cfg.max_jobs {
                    if rows.len() >= max {
                        break 'stream;
                    }
                }
            }
            _ => {} // QUEUE/ENABLE/UPDATE/... — no lifecycle effect.
        }
    }

    let skipped_incomplete = open.len();
    Ok(GoogleTraceSource {
        specs: finalize_rows(rows, cfg.load_scale).into_iter(),
        tenant_names: interner.into_names(),
        skipped_zero_gpu,
        skipped_unscheduled,
        skipped_incomplete,
        machines: None,
    })
}

impl WorkloadSource for GoogleTraceSource {
    fn name(&self) -> &'static str {
        "google-trace"
    }

    fn next_spec(&mut self) -> Option<JobSpec> {
        self.specs.next()
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.specs.len())
    }

    fn tenant_names(&self) -> Vec<String> {
        self.tenant_names.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{JobId, TenantId};

    // Two collections on two users; c=2 schedules twice (evicted once).
    const SMALL: &str = "\
# tiny instance-event projection
time,type,collection_id,user,cpus
1000000,0,1,alice,0.25
2000000,0,2,bob,0.05
3000000,3,1,alice,0.25
4000000,3,2,bob,0.05
5000000,4,2,bob,0.05
6000000,6,1,alice,0.25
7000000,0,2,bob,0.05
8000000,3,2,bob,0.05
10000000,6,2,bob,0.05
";

    #[test]
    fn parses_lifecycle_and_sorts_by_arrival() {
        let mut src =
            GoogleTraceSource::from_str(SMALL, &GoogleTraceConfig::default())
                .unwrap();
        assert_eq!(src.tenant_names(), vec!["alice", "bob"]);
        let specs: Vec<JobSpec> =
            std::iter::from_fn(|| src.next_spec()).collect();
        assert_eq!(specs.len(), 2);
        // Arrivals re-based to the earliest submit (t=1s); c=2 keeps its
        // first submit (t=2s) across the evict + re-submit.
        assert_eq!(specs[0].arrival_s, 0.0);
        assert_eq!(specs[0].id, JobId(0));
        assert_eq!(specs[1].arrival_s, 1.0);
        // Durations: schedule→finish. c=1: 6s−3s = 3s. c=2: the evict
        // cleared the first schedule, so 10s−8s = 2s.
        assert_eq!(specs[0].duration_s, 3.0);
        assert_eq!(specs[1].duration_s, 2.0);
        // gpus = ceil(cpus_norm × 8): 0.25→2, 0.05→1.
        assert_eq!(specs[0].gpus, 2);
        assert_eq!(specs[1].gpus, 1);
        assert_eq!(specs[0].tenant, TenantId(0));
        assert_eq!(specs[1].tenant, TenantId(1));
    }

    #[test]
    fn multiplier_file_overrides_config() {
        let mult = "cpus,memory\n64,256\n";
        let mut src = GoogleTraceSource::from_parts(
            SMALL,
            None,
            Some(mult),
            &GoogleTraceConfig { gpu_cap: 0, ..GoogleTraceConfig::default() },
        )
        .unwrap();
        let specs: Vec<JobSpec> =
            std::iter::from_fn(|| src.next_spec()).collect();
        // ceil(0.25 × 64) = 16, ceil(0.05 × 64) = 4.
        assert_eq!(specs[0].gpus, 16);
        assert_eq!(specs[1].gpus, 4);
        // gpu_cap still applies on top of the multiplier.
        let mut capped = GoogleTraceSource::from_parts(
            SMALL,
            None,
            Some(mult),
            &GoogleTraceConfig { gpu_cap: 8, ..GoogleTraceConfig::default() },
        )
        .unwrap();
        assert_eq!(capped.next_spec().unwrap().gpus, 8);
        // A malformed multiplier row errors rather than silently
        // falling back.
        assert!(GoogleTraceSource::from_parts(
            SMALL,
            None,
            Some("cpus\n-3\n"),
            &GoogleTraceConfig::default(),
        )
        .is_err());
    }

    #[test]
    fn machine_events_give_fleet_hint() {
        let mach = "\
time,machine_id,type
0,100,0
0,101,0
0,102,0
50,101,1
";
        let src = GoogleTraceSource::from_parts(
            SMALL,
            Some(mach),
            None,
            &GoogleTraceConfig::default(),
        )
        .unwrap();
        assert_eq!(src.machines(), Some(2));
        assert!(GoogleTraceSource::from_parts(
            SMALL,
            Some("time,machine_id,type\n0,x,0\n"),
            None,
            &GoogleTraceConfig::default(),
        )
        .unwrap_err()
        .contains("line 2"));
    }

    #[test]
    fn malformed_rows_report_line_numbers() {
        let cfg = GoogleTraceConfig::default();
        for (bad, what) in [
            ("time,type,collection_id,cpus\nx,0,1,0.5\n", "time"),
            ("time,type,collection_id,cpus\n0,zero,1,0.5\n", "type"),
            ("time,type,collection_id,cpus\n0,0,1\n", "cpus"),
        ] {
            let err = GoogleTraceSource::from_str(bad, &cfg).unwrap_err();
            assert!(err.contains("line 2"), "{what}: {err}");
        }
        // Missing a required column names the column.
        let err = GoogleTraceSource::from_str("time,type,cpus\n", &cfg)
            .unwrap_err();
        assert!(err.contains("collection_id"), "{err}");
        // FINISH before SCHEDULE time is a hard error.
        let bad = "\
time,type,collection_id,cpus
0,0,1,0.5
9000000,3,1,0.5
5000000,6,1,0.5
";
        let err = GoogleTraceSource::from_str(bad, &cfg).unwrap_err();
        assert!(err.contains("line 4"), "{err}");
    }

    #[test]
    fn zero_cpu_collections_skip_before_interning_and_rng() {
        // Collection 9 (user zed, 0 cpus) completes first; the kept
        // rows' tenant ids and sampled models must match a trace that
        // never contained it.
        const WITH_ZERO: &str = "\
time,type,collection_id,user,cpus
0,0,9,zed,0
1000000,0,1,alice,0.5
2000000,3,9,zed,0
3000000,3,1,alice,0.5
4000000,6,9,zed,0
5000000,6,1,alice,0.5
";
        const PRE_FILTERED: &str = "\
time,type,collection_id,user,cpus
1000000,0,1,alice,0.5
3000000,3,1,alice,0.5
5000000,6,1,alice,0.5
";
        let cfg = GoogleTraceConfig::default();
        let mut with =
            GoogleTraceSource::from_str(WITH_ZERO, &cfg).unwrap();
        let mut pre =
            GoogleTraceSource::from_str(PRE_FILTERED, &cfg).unwrap();
        assert_eq!(with.skipped_zero_gpu(), 1);
        assert_eq!(pre.skipped_zero_gpu(), 0);
        assert_eq!(with.tenant_names(), pre.tenant_names());
        let a: Vec<JobSpec> =
            std::iter::from_fn(|| with.next_spec()).collect();
        let b: Vec<JobSpec> =
            std::iter::from_fn(|| pre.next_spec()).collect();
        assert_eq!(a.len(), 1);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.model, y.model);
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.gpus, y.gpus);
            assert_eq!(x.arrival_s, y.arrival_s);
        }
    }

    #[test]
    fn kills_drop_unless_keep_failed() {
        let trace = "\
time,type,collection_id,user,cpus
0,0,1,a,0.5
1000000,3,1,a,0.5
2000000,7,1,a,0.5
";
        let cfg = GoogleTraceConfig::default();
        let mut src = GoogleTraceSource::from_str(trace, &cfg).unwrap();
        assert!(src.next_spec().is_none());
        let mut kept = GoogleTraceSource::from_str(
            trace,
            &GoogleTraceConfig { keep_failed: true, ..cfg },
        )
        .unwrap();
        let s = kept.next_spec().unwrap();
        assert_eq!(s.duration_s, 1.0);
    }

    #[test]
    fn unscheduled_and_incomplete_are_counted() {
        // c=1 finishes without ever scheduling; c=2 never terminates.
        let trace = "\
time,type,collection_id,user,cpus
0,0,1,a,0.5
1000000,6,1,a,0.5
2000000,0,2,b,0.5
3000000,3,2,b,0.5
";
        let src = GoogleTraceSource::from_str(
            trace,
            &GoogleTraceConfig::default(),
        )
        .unwrap();
        assert_eq!(src.skipped_unscheduled(), 1);
        assert_eq!(src.skipped_incomplete(), 1);
        assert_eq!(src.len_hint(), Some(0));
    }

    #[test]
    fn max_jobs_truncates_and_sampling_is_deterministic() {
        let cfg = GoogleTraceConfig {
            max_jobs: Some(1),
            ..GoogleTraceConfig::default()
        };
        let mut src = GoogleTraceSource::from_str(SMALL, &cfg).unwrap();
        assert_eq!(src.len_hint(), Some(1));
        assert!(src.next_spec().is_some());
        assert!(src.next_spec().is_none());
        let take = |seed: u64| -> Vec<crate::job::ModelKind> {
            let cfg = GoogleTraceConfig {
                seed,
                ..GoogleTraceConfig::default()
            };
            let mut src =
                GoogleTraceSource::from_str(SMALL, &cfg).unwrap();
            std::iter::from_fn(|| src.next_spec()).map(|s| s.model).collect()
        };
        assert_eq!(take(7), take(7));
    }
}
