//! Tenant-aware weighted-quota admission (the multi-tenant half of the
//! paper's setting: §1 "multi-tenant clusters" / the Philly analysis
//! paper's per-VC queues).
//!
//! Each tenant holds a weight; a round's GPU capacity is apportioned to
//! the tenants *present in the queue* by largest-remainder rounding of
//! `total_gpus × wᵗ / Σw`. Admission walks the policy-ordered queue twice:
//!
//! 1. **Quota pass** — admit a job only while its tenant stays within its
//!    integer GPU cap (and the cluster total).
//! 2. **Spill pass (work-conserving)** — capacity a tenant could not use
//!    (no demand, or gang sizes that don't pack) is handed to the
//!    remaining jobs in policy order, so GPUs never idle because of
//!    quotas alone.
//!
//! With no quotas configured the single-pass behaviour is byte-identical
//! to the pre-tenancy coordinator: admit in policy order while aggregate
//! GPU demand fits, passing over too-big jobs (gang backfill).

use crate::job::{JobId, TenantId};
use std::collections::BTreeMap;

/// Per-tenant scheduling weights. Tenants absent from the map default to
/// weight 1.0, so partially specified quota sets degrade gracefully.
#[derive(Debug, Clone, Default)]
pub struct TenantQuotas {
    weights: BTreeMap<TenantId, f64>,
}

impl TenantQuotas {
    pub fn new() -> TenantQuotas {
        TenantQuotas::default()
    }

    /// Set one tenant's weight (must be positive).
    pub fn set(&mut self, tenant: TenantId, weight: f64) {
        assert!(weight > 0.0, "tenant weight must be positive");
        self.weights.insert(tenant, weight);
    }

    /// Builder-style [`TenantQuotas::set`].
    pub fn with(mut self, tenant: TenantId, weight: f64) -> TenantQuotas {
        self.set(tenant, weight);
        self
    }

    /// The weight of `tenant` (1.0 when unspecified).
    pub fn weight(&self, tenant: TenantId) -> f64 {
        self.weights.get(&tenant).copied().unwrap_or(1.0)
    }

    /// Number of explicitly configured tenants.
    pub fn len(&self) -> usize {
        self.weights.len()
    }

    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }

    /// Integer GPU caps for the tenants in `present`, apportioning
    /// `total_gpus` by weight with largest-remainder rounding (ties break
    /// toward the lower tenant id for determinism). Caps sum to
    /// `total_gpus` whenever `present` is non-empty.
    pub fn integer_caps(
        &self,
        present: &[TenantId],
        total_gpus: u32,
    ) -> BTreeMap<TenantId, u32> {
        let mut caps: BTreeMap<TenantId, u32> = BTreeMap::new();
        if present.is_empty() {
            return caps;
        }
        let total_weight: f64 =
            present.iter().map(|&t| self.weight(t)).sum();
        let mut fractions: Vec<(TenantId, f64)> = Vec::new();
        let mut assigned = 0u32;
        for &t in present {
            let exact =
                total_gpus as f64 * self.weight(t) / total_weight;
            let base = exact.floor() as u32;
            caps.insert(t, base);
            assigned += base;
            fractions.push((t, exact - base as f64));
        }
        // Hand out the remainder to the largest fractional parts.
        let mut leftover = total_gpus - assigned;
        fractions.sort_by(|a, b| {
            b.1.total_cmp(&a.1).then(a.0.cmp(&b.0))
        });
        for (t, _) in fractions {
            if leftover == 0 {
                break;
            }
            *caps.get_mut(&t).unwrap() += 1;
            leftover -= 1;
        }
        caps
    }
}

/// The admission-relevant facts of one queued job.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionJob {
    pub id: JobId,
    pub tenant: TenantId,
    pub gpus: u32,
}

/// Outcome of one admission round (inputs to the mechanism + audit trail).
#[derive(Debug, Clone, Default)]
pub struct AdmissionOutcome {
    /// Admitted job ids: quota-pass admits in policy order, then spill
    /// admits in policy order (spilled jobs rank below in-quota jobs).
    pub admitted: Vec<JobId>,
    /// For each entry of `admitted`, its position in the input `ordered`
    /// slice. Lets callers that keep per-job data parallel to the queue
    /// (the simulation core's arena indices) map the admitted set back
    /// without a lookup per job.
    pub positions: Vec<usize>,
    /// GPUs admitted per tenant (for fairness accounting).
    pub gpus_by_tenant: BTreeMap<TenantId, u32>,
    /// Jobs admitted only by the work-conserving spill pass.
    pub spilled: Vec<JobId>,
    /// Of [`AdmissionOutcome::gpus_by_tenant`], the GPUs a tenant won
    /// through the spill pass — capacity another tenant's quota left
    /// stranded (telemetry: per-tenant spill series). Empty on the
    /// quota-free fast path, which never spills.
    pub spilled_gpus_by_tenant: BTreeMap<TenantId, u32>,
}

/// Admit jobs from the policy-ordered queue into `total_gpus` of capacity.
///
/// `quotas = None` reproduces the quota-free admission exactly (single
/// pass, gang backfill) on a fast path that skips all per-tenant
/// bookkeeping — `gpus_by_tenant` is populated only when quotas are on.
/// See the module docs for the two-pass semantics with quotas.
pub fn admit(
    ordered: &[AdmissionJob],
    total_gpus: u32,
    quotas: Option<&TenantQuotas>,
) -> AdmissionOutcome {
    let mut out = AdmissionOutcome::default();
    let mut used = 0u32;

    // Fast path: the scheduler hot loop runs single-tenant by default.
    let Some(quotas) = quotas else {
        for (pos, job) in ordered.iter().enumerate() {
            if used + job.gpus <= total_gpus {
                used += job.gpus;
                out.admitted.push(job.id);
                out.positions.push(pos);
            }
        }
        return out;
    };

    let caps = {
        let mut present: Vec<TenantId> =
            ordered.iter().map(|j| j.tenant).collect();
        present.sort_unstable();
        present.dedup();
        quotas.integer_caps(&present, total_gpus)
    };

    // Pass 1: within-quota.
    let mut deferred: Vec<(usize, AdmissionJob)> = Vec::new();
    for (pos, job) in ordered.iter().enumerate() {
        if used + job.gpus > total_gpus {
            continue; // passed over; smaller later jobs may backfill
        }
        let cap = caps.get(&job.tenant).copied().unwrap_or(0);
        let t_used =
            out.gpus_by_tenant.get(&job.tenant).copied().unwrap_or(0);
        if t_used + job.gpus > cap {
            deferred.push((pos, *job));
            continue;
        }
        used += job.gpus;
        *out.gpus_by_tenant.entry(job.tenant).or_insert(0) += job.gpus;
        out.admitted.push(job.id);
        out.positions.push(pos);
    }

    // Pass 2: work-conserving spill of capacity quotas left stranded.
    for &(pos, ref job) in &deferred {
        if used + job.gpus > total_gpus {
            continue;
        }
        used += job.gpus;
        *out.gpus_by_tenant.entry(job.tenant).or_insert(0) += job.gpus;
        *out.spilled_gpus_by_tenant.entry(job.tenant).or_insert(0) +=
            job.gpus;
        out.admitted.push(job.id);
        out.positions.push(pos);
        out.spilled.push(job.id);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, tenant: u32, gpus: u32) -> AdmissionJob {
        AdmissionJob { id: JobId(id), tenant: TenantId(tenant), gpus }
    }

    #[test]
    fn no_quotas_matches_gang_backfill() {
        // 8 GPUs: 6 fits, 8 passed over, 2 backfills.
        let q = [job(0, 0, 6), job(1, 0, 8), job(2, 0, 2)];
        let out = admit(&q, 8, None);
        assert_eq!(out.admitted, vec![JobId(0), JobId(2)]);
        assert!(out.spilled.is_empty());
    }

    #[test]
    fn positions_track_input_slots() {
        // Fast path: positions mirror the admitted subsequence.
        let q = [job(0, 0, 6), job(1, 0, 8), job(2, 0, 2)];
        let out = admit(&q, 8, None);
        assert_eq!(out.positions, vec![0, 2]);
        // Quota + spill path: positions follow the admitted order, which
        // interleaves pass-1 and pass-2 admits.
        let q = [job(0, 1, 8), job(1, 0, 4), job(2, 0, 4)];
        let quotas = TenantQuotas::new()
            .with(TenantId(0), 1.0)
            .with(TenantId(1), 1.0);
        let out = admit(&q, 8, Some(&quotas));
        assert_eq!(out.admitted, vec![JobId(1), JobId(2)]);
        assert_eq!(out.positions, vec![1, 2]);
    }

    #[test]
    fn integer_caps_sum_to_total() {
        let quotas = TenantQuotas::new()
            .with(TenantId(0), 2.0)
            .with(TenantId(1), 1.0);
        let caps = quotas
            .integer_caps(&[TenantId(0), TenantId(1)], 8);
        assert_eq!(caps[&TenantId(0)] + caps[&TenantId(1)], 8);
        // 2:1 over 8 GPUs → 5.33 : 2.67 → largest remainder gives 5:3.
        assert_eq!(caps[&TenantId(0)], 5);
        assert_eq!(caps[&TenantId(1)], 3);
    }

    #[test]
    fn contended_tenants_capped_at_weighted_share() {
        // Both tenants queue far more 1-GPU jobs than their cap; neither
        // may exceed it.
        let mut q = Vec::new();
        for i in 0..16 {
            q.push(job(i, (i % 2) as u32, 1));
        }
        let quotas = TenantQuotas::new()
            .with(TenantId(0), 3.0)
            .with(TenantId(1), 1.0);
        let out = admit(&q, 8, Some(&quotas));
        assert_eq!(out.admitted.len(), 8);
        assert_eq!(out.gpus_by_tenant[&TenantId(0)], 6);
        assert_eq!(out.gpus_by_tenant[&TenantId(1)], 2);
        assert!(out.spilled.is_empty(), "contended: nothing to spill");
    }

    #[test]
    fn spill_is_work_conserving() {
        // Tenant 1 has no demand; tenant 0 absorbs the whole cluster.
        let q = [job(0, 0, 4), job(1, 0, 4)];
        let quotas = TenantQuotas::new()
            .with(TenantId(0), 1.0)
            .with(TenantId(1), 1.0);
        let out = admit(&q, 8, Some(&quotas));
        // Only tenant 0 is *present*, so it owns the full capacity.
        assert_eq!(out.admitted.len(), 2);
        assert_eq!(out.gpus_by_tenant[&TenantId(0)], 8);
    }

    #[test]
    fn spill_fills_gang_fragmentation() {
        // Tenant 1's cap is 4 but its only job needs 8 GPUs: its quota
        // strands and tenant 0's deferred job takes the space.
        let q = [job(0, 1, 8), job(1, 0, 4), job(2, 0, 4)];
        let quotas = TenantQuotas::new()
            .with(TenantId(0), 1.0)
            .with(TenantId(1), 1.0);
        let out = admit(&q, 8, Some(&quotas));
        assert_eq!(out.admitted, vec![JobId(1), JobId(2)]);
        assert_eq!(out.spilled, vec![JobId(2)]);
        assert_eq!(out.gpus_by_tenant[&TenantId(0)], 8);
        // The spill tally attributes exactly the pass-2 GPUs.
        assert_eq!(out.spilled_gpus_by_tenant[&TenantId(0)], 4);
        assert_eq!(out.spilled_gpus_by_tenant.len(), 1);
    }

    #[test]
    fn unknown_tenants_default_to_weight_one() {
        let quotas = TenantQuotas::new().with(TenantId(0), 1.0);
        let caps = quotas.integer_caps(
            &[TenantId(0), TenantId(7)],
            8,
        );
        assert_eq!(caps[&TenantId(0)], 4);
        assert_eq!(caps[&TenantId(7)], 4);
    }

    #[test]
    fn deterministic_tie_break_on_remainders() {
        let quotas = TenantQuotas::new()
            .with(TenantId(0), 1.0)
            .with(TenantId(1), 1.0)
            .with(TenantId(2), 1.0);
        // 8 / 3 → 2.67 each: two tenants get 3, lowest ids first.
        let caps = quotas.integer_caps(
            &[TenantId(0), TenantId(1), TenantId(2)],
            8,
        );
        assert_eq!(caps[&TenantId(0)], 3);
        assert_eq!(caps[&TenantId(1)], 3);
        assert_eq!(caps[&TenantId(2)], 2);
    }
}
