//! Alibaba-style machine-utilization trace adapter (paper §5.7: "big
//! data" workloads scheduled with DRF/Tetris-style static allocation).
//!
//! The Alibaba cluster-trace `machine_usage` table is a time series of
//! per-machine CPU/memory utilization, not a job log. This adapter maps
//! each utilization entry onto the *big-data job families* the paper's
//! §5.7 comparison uses with the `Fixed` mechanism and DRF/Tetris
//! policies:
//!
//! - **CPU-heavy** entries (`cpu ≥ cpu_heavy_pct`) become image-family
//!   jobs (AlexNet / ShuffleNetV2): high CPU knee, the canonical
//!   CPU-sensitive family.
//! - **Memory-heavy** entries (`mem ≥ mem_heavy_pct`) become the
//!   cache-hungry family (ResNet18-OpenImages / M5).
//! - Entries heavy on **both** dimensions go to whichever utilization
//!   is higher; everything else becomes language-family filler
//!   (Lstm / Gnmt): insensitive jobs that static allocation serves well.
//!
//! GPU demand and duration scale deterministically with the entry's
//! intensity, so a hotter machine produces a bigger, longer job. Each
//! machine id becomes a tenant, which makes the `machine_usage` slice a
//! ready-made multi-tenant contention workload.
//!
//! Expected CSV columns (header required, extra columns ignored):
//!
//! ```text
//! timestamp,machine_id,cpu_util_percent,mem_util_percent
//! 0,m_1,85,40
//! ```

use super::{
    finalize_rows, CsvDoc, JobSpec, RawRow, TenantInterner, WorkloadSource,
};
use crate::job::ModelKind;
use crate::util::rng::Pcg64;

/// Adapter configuration.
#[derive(Debug, Clone)]
pub struct AlibabaTraceConfig {
    pub path: String,
    /// λ rescale, as in [`super::PhillyTraceConfig::load_scale`].
    pub load_scale: f64,
    /// CPU-utilization threshold (percent) for the CPU-heavy family.
    pub cpu_heavy_pct: f64,
    /// Memory-utilization threshold (percent) for the memory-heavy family.
    pub mem_heavy_pct: f64,
    /// Keep only the first N data rows (file order).
    pub max_jobs: Option<usize>,
    /// Seed for the within-family model choice.
    pub seed: u64,
}

impl Default for AlibabaTraceConfig {
    fn default() -> Self {
        AlibabaTraceConfig {
            path: String::new(),
            load_scale: 1.0,
            cpu_heavy_pct: 60.0,
            mem_heavy_pct: 60.0,
            max_jobs: None,
            seed: 1,
        }
    }
}

/// A parsed Alibaba-style utilization trace, streamed in arrival order.
pub struct AlibabaTraceSource {
    specs: std::vec::IntoIter<JobSpec>,
    tenant_names: Vec<String>,
}

impl AlibabaTraceSource {
    pub fn new(cfg: AlibabaTraceConfig) -> Result<AlibabaTraceSource, String> {
        if !(cfg.load_scale > 0.0) {
            return Err("load_scale must be positive".to_string());
        }
        let text = std::fs::read_to_string(&cfg.path)
            .map_err(|e| format!("read {}: {e}", cfg.path))?;
        Self::from_str(&text, &cfg)
    }

    /// Parse from an in-memory CSV document.
    pub fn from_str(
        text: &str,
        cfg: &AlibabaTraceConfig,
    ) -> Result<AlibabaTraceSource, String> {
        let doc = CsvDoc::parse(text)?;
        let c_ts = doc.require_column("timestamp")?;
        let c_machine = doc.require_column("machine_id")?;
        let c_cpu = doc.require_column("cpu_util_percent")?;
        let c_mem = doc.require_column("mem_util_percent")?;

        let mut rng = Pcg64::new(cfg.seed, 0xA11BA);
        let mut interner = TenantInterner::new();
        let mut rows: Vec<RawRow> = Vec::new();

        for row in doc.rows() {
            if let Some(max) = cfg.max_jobs {
                if rows.len() >= max {
                    break;
                }
            }
            let ts: f64 = row.parse(c_ts, "timestamp")?;
            let cpu: f64 = row.parse(c_cpu, "cpu_util_percent")?;
            let mem: f64 = row.parse(c_mem, "mem_util_percent")?;
            if !(0.0..=100.0).contains(&cpu)
                || !(0.0..=100.0).contains(&mem)
            {
                return Err(format!(
                    "line {}: utilization must be in [0, 100]",
                    row.line_no
                ));
            }
            let tenant = interner.intern(row.cell(c_machine)?);
            // Family thresholds (§5.7 job families); an entry heavy on
            // *both* dimensions goes to the dominant one.
            let cpu_heavy = cpu >= cfg.cpu_heavy_pct;
            let mem_heavy = mem >= cfg.mem_heavy_pct;
            let model = if cpu_heavy && (!mem_heavy || cpu >= mem) {
                *rng.choose(&[ModelKind::AlexNet, ModelKind::ShuffleNetV2])
            } else if mem_heavy {
                *rng.choose(&[ModelKind::ResNet18, ModelKind::M5])
            } else {
                *rng.choose(&[ModelKind::Lstm, ModelKind::Gnmt])
            };
            // Intensity → gang size and duration (deterministic).
            let intensity = cpu.max(mem);
            let gpus = if intensity >= 80.0 {
                4
            } else if intensity >= 50.0 {
                2
            } else {
                1
            };
            let duration_s =
                (60.0 + (cpu + mem) / 200.0 * 7200.0).clamp(60.0, 7260.0);
            rows.push((ts, tenant, model, gpus, duration_s));
        }

        Ok(AlibabaTraceSource {
            specs: finalize_rows(rows, cfg.load_scale).into_iter(),
            tenant_names: interner.into_names(),
        })
    }
}

impl WorkloadSource for AlibabaTraceSource {
    fn name(&self) -> &'static str {
        "alibaba-usage"
    }

    fn next_spec(&mut self) -> Option<JobSpec> {
        self.specs.next()
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.specs.len())
    }

    fn tenant_names(&self) -> Vec<String> {
        self.tenant_names.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{Task, TenantId};

    const SMALL: &str = "\
timestamp,machine_id,cpu_util_percent,mem_util_percent
0,m_1,85,40
30,m_2,20,75
60,m_1,30,30
";

    #[test]
    fn maps_families_by_pressure() {
        let mut src = AlibabaTraceSource::from_str(
            SMALL,
            &AlibabaTraceConfig::default(),
        )
        .unwrap();
        let specs: Vec<JobSpec> =
            std::iter::from_fn(|| src.next_spec()).collect();
        assert_eq!(specs.len(), 3);
        // 85% CPU → image family, 4 GPUs.
        assert_eq!(specs[0].model.task(), Task::Image);
        assert_eq!(specs[0].gpus, 4);
        // 75% mem → memory-heavy family (image or speech zoo entries).
        assert!(matches!(
            specs[1].model,
            ModelKind::ResNet18 | ModelKind::M5
        ));
        assert_eq!(specs[1].gpus, 2);
        // Cool machine → language filler, 1 GPU.
        assert_eq!(specs[2].model.task(), Task::Language);
        assert_eq!(specs[2].gpus, 1);
    }

    #[test]
    fn tenants_from_machines() {
        let mut src = AlibabaTraceSource::from_str(
            SMALL,
            &AlibabaTraceConfig::default(),
        )
        .unwrap();
        assert_eq!(src.tenant_names(), vec!["m_1", "m_2"]);
        let specs: Vec<JobSpec> =
            std::iter::from_fn(|| src.next_spec()).collect();
        assert_eq!(specs[0].tenant, TenantId(0));
        assert_eq!(specs[1].tenant, TenantId(1));
        assert_eq!(specs[2].tenant, TenantId(0));
    }

    #[test]
    fn deterministic_and_rescalable() {
        let run = || -> Vec<JobSpec> {
            let cfg = AlibabaTraceConfig {
                load_scale: 3.0,
                ..AlibabaTraceConfig::default()
            };
            let mut src =
                AlibabaTraceSource::from_str(SMALL, &cfg).unwrap();
            std::iter::from_fn(|| src.next_spec()).collect()
        };
        let a = run();
        assert_eq!(a, run());
        assert_eq!(a[2].arrival_s, 20.0); // 60 / 3
    }

    #[test]
    fn rejects_out_of_range_utilization() {
        let bad = "timestamp,machine_id,cpu_util_percent,mem_util_percent\n0,m,150,10\n";
        assert!(AlibabaTraceSource::from_str(
            bad,
            &AlibabaTraceConfig::default()
        )
        .is_err());
    }
}
