//! Philly-format CSV trace reader (paper §5.3.1; format after the Philly
//! analysis paper, arXiv:1901.05758).
//!
//! The public Philly release is per-job rows with a virtual-cluster (VC)
//! tag; this reader ingests a flat CSV projection of it:
//!
//! ```text
//! job_id,vc,submit_time,gpus,duration_s,model,status
//! j1,vc-a,0,1,3600,resnet18,Pass
//! ```
//!
//! - **Required columns:** `submit_time` (seconds, any epoch — arrivals
//!   are re-based to the earliest kept row), `gpus`, `duration_s`.
//! - **Optional columns:** `vc` (tenant; defaults to a single `default`
//!   tenant), `model` (a zoo name from `synergy models`; rows without a
//!   model are sampled from the configured [`Split`]), `status` (only
//!   `Pass` rows are kept unless [`keep_failed`] is set), `job_id`
//!   (ignored — ids are re-assigned densely in arrival order).
//! - Blank lines and `#` comments are skipped. Cells must not contain
//!   commas (the Philly projection never does).
//! - Rows with `gpus == 0` (the public dump's CPU-only jobs) are
//!   skipped and counted ([`PhillyTraceSource::skipped_zero_gpu`])
//!   rather than hard-erroring the whole file; a non-positive
//!   `duration_s` is still an error. The skip happens before tenant
//!   interning and model sampling, so the kept rows' tenant ids and
//!   RNG stream are identical to a trace without those rows.
//!
//! Load-scaling / time-warp knobs: [`load_scale`] divides every
//! inter-arrival gap (λ rescale), [`duration_min_s`]/[`duration_max_s`]
//! clamp durations, and [`gpu_cap`] remaps outsized GPU demands down to
//! the largest gang the target cluster supports.
//!
//! [`keep_failed`]: PhillyTraceConfig::keep_failed
//! [`load_scale`]: PhillyTraceConfig::load_scale
//! [`duration_min_s`]: PhillyTraceConfig::duration_min_s
//! [`duration_max_s`]: PhillyTraceConfig::duration_max_s
//! [`gpu_cap`]: PhillyTraceConfig::gpu_cap

use super::{
    finalize_rows, CsvDoc, JobSpec, RawRow, TenantInterner, WorkloadSource,
};
use crate::job::{ModelKind, TenantId};
use crate::trace::{Split, SPLIT_DEFAULT};
use crate::util::rng::Pcg64;

/// Reader configuration (see module docs for knob semantics).
#[derive(Debug, Clone)]
pub struct PhillyTraceConfig {
    pub path: String,
    /// λ rescale: all inter-arrival gaps are divided by this (>1
    /// compresses the trace onto a busier cluster). Must be positive.
    pub load_scale: f64,
    /// Duration clamp, seconds.
    pub duration_min_s: f64,
    pub duration_max_s: f64,
    /// GPU-demand remap: demands above this are clamped down (0 disables).
    pub gpu_cap: u32,
    /// Keep only the first N data rows (file order).
    pub max_jobs: Option<usize>,
    /// Model mix for rows without a `model` column.
    pub split: Split,
    /// Seed for model sampling of model-less rows.
    pub seed: u64,
    /// Keep rows whose `status` is not `Pass`.
    pub keep_failed: bool,
}

impl Default for PhillyTraceConfig {
    fn default() -> Self {
        PhillyTraceConfig {
            path: String::new(),
            load_scale: 1.0,
            duration_min_s: 1.0,
            duration_max_s: f64::INFINITY,
            gpu_cap: 16,
            max_jobs: None,
            split: SPLIT_DEFAULT,
            seed: 1,
            keep_failed: false,
        }
    }
}

/// A parsed Philly-format trace, streamed in arrival order.
pub struct PhillyTraceSource {
    specs: std::vec::IntoIter<JobSpec>,
    tenant_names: Vec<String>,
    skipped_zero_gpu: usize,
}

impl PhillyTraceSource {
    /// Read and parse `cfg.path`. Errors carry the offending line number.
    pub fn new(cfg: PhillyTraceConfig) -> Result<PhillyTraceSource, String> {
        if !(cfg.load_scale > 0.0) {
            return Err("load_scale must be positive".to_string());
        }
        if !(cfg.duration_min_s <= cfg.duration_max_s) {
            return Err("duration clamp: min > max".to_string());
        }
        let text = std::fs::read_to_string(&cfg.path)
            .map_err(|e| format!("read {}: {e}", cfg.path))?;
        Self::from_str(&text, &cfg)
    }

    /// Parse from an in-memory CSV document (used by tests and benches).
    pub fn from_str(
        text: &str,
        cfg: &PhillyTraceConfig,
    ) -> Result<PhillyTraceSource, String> {
        let doc = CsvDoc::parse(text)?;
        let c_submit = doc.require_column("submit_time")?;
        let c_gpus = doc.require_column("gpus")?;
        let c_dur = doc.require_column("duration_s")?;
        let c_vc = doc.column("vc");
        let c_model = doc.column("model");
        let c_status = doc.column("status");

        let mut rng = Pcg64::new(cfg.seed, 0x9B177);
        let mut interner = TenantInterner::new();
        let mut skipped_zero_gpu = 0usize;
        // (submit, tenant, model, gpus, duration), file order.
        let mut rows: Vec<RawRow> = Vec::new();

        for row in doc.rows() {
            if let Some(max) = cfg.max_jobs {
                if rows.len() >= max {
                    break;
                }
            }
            if let Some(ci) = c_status {
                let status = row.cell(ci)?;
                if !cfg.keep_failed && !status.eq_ignore_ascii_case("pass")
                {
                    continue;
                }
            }
            let submit: f64 = row.parse(c_submit, "submit_time")?;
            let gpus_raw: u32 = row.parse(c_gpus, "gpus")?;
            let duration: f64 = row.parse(c_dur, "duration_s")?;
            if gpus_raw == 0 {
                // CPU-only rows exist in the public dump; they cannot
                // gang-schedule, so count-and-skip before interning or
                // model sampling (keeps kept rows byte-identical to a
                // pre-filtered trace).
                skipped_zero_gpu += 1;
                continue;
            }
            if !duration.is_finite() || duration <= 0.0 {
                return Err(format!(
                    "line {}: duration_s must be positive",
                    row.line_no
                ));
            }
            let tenant = match c_vc {
                None => TenantId::DEFAULT,
                Some(ci) => {
                    let vc = row.cell(ci)?;
                    interner.intern(if vc.is_empty() { "default" } else { vc })
                }
            };
            let model_name = match c_model {
                Some(ci) => row.cell(ci)?,
                None => "",
            };
            let model = if model_name.is_empty() {
                cfg.split.sample_model(&mut rng)
            } else {
                ModelKind::from_name(model_name).ok_or_else(|| {
                    format!(
                        "line {}: unknown model '{model_name}'",
                        row.line_no
                    )
                })?
            };
            let gpus = if cfg.gpu_cap > 0 {
                gpus_raw.min(cfg.gpu_cap)
            } else {
                gpus_raw
            };
            let duration = duration
                .clamp(cfg.duration_min_s, cfg.duration_max_s);
            rows.push((submit, tenant, model, gpus, duration));
        }

        if skipped_zero_gpu > 0 {
            eprintln!(
                "philly trace{}: skipped {skipped_zero_gpu} zero-GPU row(s)",
                if cfg.path.is_empty() {
                    String::new()
                } else {
                    format!(" {}", cfg.path)
                }
            );
        }
        Ok(PhillyTraceSource {
            specs: finalize_rows(rows, cfg.load_scale).into_iter(),
            tenant_names: interner.into_names(),
            skipped_zero_gpu,
        })
    }

    /// Rows dropped because their `gpus` column was 0 (CPU-only jobs in
    /// the public Philly dump).
    pub fn skipped_zero_gpu(&self) -> usize {
        self.skipped_zero_gpu
    }
}

impl WorkloadSource for PhillyTraceSource {
    fn name(&self) -> &'static str {
        "philly-csv"
    }

    fn next_spec(&mut self) -> Option<JobSpec> {
        self.specs.next()
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.specs.len())
    }

    fn tenant_names(&self) -> Vec<String> {
        self.tenant_names.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::JobId;

    const SMALL: &str = "\
# tiny hand-rolled trace
job_id,vc,submit_time,gpus,duration_s,model,status
j0,vc-a,100,1,3600,resnet18,Pass
j1,vc-b,40,2,7200,gnmt,Pass
j2,vc-a,70,32,1800,,Pass
j3,vc-b,90,1,60,lstm,Killed
";

    #[test]
    fn parses_and_sorts_by_arrival() {
        let src = PhillyTraceSource::from_str(
            SMALL,
            &PhillyTraceConfig::default(),
        )
        .unwrap();
        assert_eq!(src.tenant_names(), vec!["vc-a", "vc-b"]);
        let mut src = src;
        let specs: Vec<JobSpec> =
            std::iter::from_fn(|| src.next_spec()).collect();
        // j3 is Killed → dropped by default.
        assert_eq!(specs.len(), 3);
        // Sorted by arrival, re-based to the earliest kept row (t=40).
        assert_eq!(specs[0].arrival_s, 0.0); // j1
        assert_eq!(specs[0].gpus, 2);
        assert_eq!(specs[0].model, ModelKind::Gnmt);
        assert_eq!(specs[1].arrival_s, 30.0); // j2
        assert_eq!(specs[2].arrival_s, 60.0); // j0
        assert_eq!(specs[2].model, ModelKind::ResNet18);
        // Dense ids in arrival order.
        assert_eq!(specs[1].id, JobId(1));
        // 32-GPU demand remapped down to the 16-GPU cap.
        assert_eq!(specs[1].gpus, 16);
        // Tenant interning by first appearance: vc-a = 0, vc-b = 1.
        assert_eq!(specs[2].tenant, TenantId(0));
        assert_eq!(specs[0].tenant, TenantId(1));
    }

    #[test]
    fn keep_failed_and_load_scale() {
        let cfg = PhillyTraceConfig {
            keep_failed: true,
            load_scale: 2.0,
            ..PhillyTraceConfig::default()
        };
        let mut src =
            PhillyTraceSource::from_str(SMALL, &cfg).unwrap();
        let specs: Vec<JobSpec> =
            std::iter::from_fn(|| src.next_spec()).collect();
        assert_eq!(specs.len(), 4);
        // (100 - 40) / 2 = 30 for the last arrival.
        assert_eq!(specs.last().unwrap().arrival_s, 30.0);
    }

    #[test]
    fn duration_clamp_applies() {
        let cfg = PhillyTraceConfig {
            duration_min_s: 600.0,
            duration_max_s: 4000.0,
            ..PhillyTraceConfig::default()
        };
        let mut src =
            PhillyTraceSource::from_str(SMALL, &cfg).unwrap();
        while let Some(s) = src.next_spec() {
            assert!((600.0..=4000.0).contains(&s.duration_s));
        }
    }

    #[test]
    fn model_less_rows_sample_deterministically() {
        let take = |seed: u64| -> Vec<ModelKind> {
            let cfg =
                PhillyTraceConfig { seed, ..PhillyTraceConfig::default() };
            let mut src =
                PhillyTraceSource::from_str(SMALL, &cfg).unwrap();
            std::iter::from_fn(|| src.next_spec())
                .map(|s| s.model)
                .collect()
        };
        assert_eq!(take(7), take(7));
    }

    #[test]
    fn bad_input_reports_line() {
        let bad = "submit_time,gpus,duration_s\n10,zero,60\n";
        let err = PhillyTraceSource::from_str(
            bad,
            &PhillyTraceConfig::default(),
        )
        .unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        assert!(PhillyTraceSource::from_str(
            "nope\n",
            &PhillyTraceConfig::default()
        )
        .is_err());
    }

    #[test]
    fn zero_gpu_rows_are_skipped_and_counted() {
        // Two zero-GPU rows (one model-less) interleaved with kept rows;
        // the kept model-less row must sample the same model as in a
        // trace that never contained the zero-GPU rows.
        const WITH_ZERO: &str = "\
submit_time,vc,gpus,duration_s,model,status
10,a,0,600,,Pass
20,a,1,600,,Pass
30,b,0,600,resnet18,Pass
40,b,2,600,gnmt,Pass
";
        const PRE_FILTERED: &str = "\
submit_time,vc,gpus,duration_s,model,status
20,a,1,600,,Pass
40,b,2,600,gnmt,Pass
";
        let cfg = PhillyTraceConfig::default();
        let mut with = PhillyTraceSource::from_str(WITH_ZERO, &cfg).unwrap();
        let mut pre =
            PhillyTraceSource::from_str(PRE_FILTERED, &cfg).unwrap();
        assert_eq!(with.skipped_zero_gpu(), 2);
        assert_eq!(pre.skipped_zero_gpu(), 0);
        // Skips precede tenant interning: tenant "a" is first interned
        // at the kept t=20 row in both traces.
        assert_eq!(with.tenant_names(), pre.tenant_names());
        let a: Vec<JobSpec> = std::iter::from_fn(|| with.next_spec()).collect();
        let b: Vec<JobSpec> = std::iter::from_fn(|| pre.next_spec()).collect();
        assert_eq!(a.len(), 2);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.model, y.model);
            assert_eq!(x.gpus, y.gpus);
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.arrival_s, y.arrival_s);
        }
    }

    #[test]
    fn nonpositive_duration_still_hard_errors() {
        for dur in ["0", "-5", "nan"] {
            let bad =
                format!("submit_time,gpus,duration_s\n10,1,{dur}\n");
            let err = PhillyTraceSource::from_str(
                &bad,
                &PhillyTraceConfig::default(),
            )
            .unwrap_err();
            assert!(err.contains("line 2"), "{err}");
        }
    }

    #[test]
    fn max_jobs_truncates_in_file_order() {
        let cfg = PhillyTraceConfig {
            max_jobs: Some(2),
            keep_failed: true,
            ..PhillyTraceConfig::default()
        };
        let mut src =
            PhillyTraceSource::from_str(SMALL, &cfg).unwrap();
        assert_eq!(src.len_hint(), Some(2));
        let a = src.next_spec().unwrap();
        let b = src.next_spec().unwrap();
        assert!(src.next_spec().is_none());
        // First two file rows are j0 (t=100) and j1 (t=40).
        assert_eq!(a.arrival_s, 0.0);
        assert_eq!(b.arrival_s, 60.0);
    }
}
