//! The synthetic Philly-marginals generator behind [`WorkloadSource`].
//!
//! This is the original [`crate::trace::generate`] refactored into a
//! streaming source. The RNG call sequence per job (arrival → model →
//! GPU demand → duration) is preserved exactly, so for any
//! [`TraceConfig`] the stream is **byte-identical** to the pre-refactor
//! generator's output (guarded by a golden test in `tests/workload.rs`).
//!
//! Tenants: the base generator is single-tenant. [`with_tenants`]
//! assigns each job a tenant sampled from a [`TenantSpec`]'s weights
//! using a *separate* RNG stream, so turning tenancy on does not perturb
//! any job field — the same seed yields the same jobs, only tagged.
//!
//! [`with_tenants`]: SyntheticSource::with_tenants

use super::{JobSpec, TenantSpec, WorkloadSource};
use crate::job::{JobId, TenantId};
use crate::trace::{sample_duration_s, GpuDemandDist, TraceConfig};
use crate::util::rng::Pcg64;

/// RNG stream id of the job-field stream (shared with the historical
/// generator — do not change, or the golden test breaks).
const JOB_STREAM: u64 = 0x7EACE;
/// RNG stream id of the independent tenant-assignment stream.
const TENANT_STREAM: u64 = 0x7E7A7;

/// Streaming synthetic workload (Philly marginals, paper §5.1).
pub struct SyntheticSource {
    cfg: TraceConfig,
    rng: Pcg64,
    tenant_rng: Pcg64,
    tenants: Option<TenantSpec>,
    demand: GpuDemandDist,
    next_index: usize,
    clock_s: f64,
}

impl SyntheticSource {
    pub fn new(cfg: TraceConfig) -> SyntheticSource {
        cfg.split.validate();
        SyntheticSource {
            cfg,
            rng: Pcg64::new(cfg.seed, JOB_STREAM),
            tenant_rng: Pcg64::new(cfg.seed, TENANT_STREAM),
            tenants: None,
            demand: GpuDemandDist { multi_gpu: cfg.multi_gpu },
            next_index: 0,
            clock_s: 0.0,
        }
    }

    /// Tag jobs with tenants drawn from `spec`'s weights (independent RNG
    /// stream; job fields are unaffected).
    pub fn with_tenants(mut self, spec: TenantSpec) -> SyntheticSource {
        assert!(!spec.is_empty(), "tenant spec must name a tenant");
        self.tenants = Some(spec);
        self
    }
}

impl WorkloadSource for SyntheticSource {
    fn name(&self) -> &'static str {
        "synthetic"
    }

    fn next_spec(&mut self) -> Option<JobSpec> {
        if self.next_index >= self.cfg.n_jobs {
            return None;
        }
        let i = self.next_index;
        self.next_index += 1;
        // Identical sampling order to the historical generator.
        let arrival_s = match self.cfg.jobs_per_hour {
            None => 0.0,
            Some(lam) => {
                self.clock_s += self.rng.exponential(lam / 3600.0);
                self.clock_s
            }
        };
        let model = self.cfg.split.sample_model(&mut self.rng);
        let gpus = self.demand.sample(&mut self.rng);
        let duration_s = sample_duration_s(&mut self.rng);
        let tenant = match &self.tenants {
            None => TenantId::DEFAULT,
            Some(spec) => TenantId(
                self.tenant_rng.weighted(&spec.weights) as u32,
            ),
        };
        Some(JobSpec {
            id: JobId(i as u64),
            tenant,
            model,
            gpus,
            arrival_s,
            duration_s,
        })
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.cfg.n_jobs - self.next_index)
    }

    fn tenant_names(&self) -> Vec<String> {
        match &self.tenants {
            None => vec!["default".to_string()],
            Some(spec) => spec.names.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::Task;

    fn cfg(n: usize, seed: u64) -> TraceConfig {
        TraceConfig { n_jobs: n, seed, ..TraceConfig::default() }
    }

    #[test]
    fn deterministic_across_instances() {
        let a: Vec<JobSpec> = {
            let mut s = SyntheticSource::new(cfg(100, 5));
            std::iter::from_fn(move || s.next_spec()).collect()
        };
        let b: Vec<JobSpec> = {
            let mut s = SyntheticSource::new(cfg(100, 5));
            std::iter::from_fn(move || s.next_spec()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn tenant_tagging_leaves_job_fields_unchanged() {
        let plain: Vec<JobSpec> = {
            let mut s = SyntheticSource::new(cfg(200, 9));
            std::iter::from_fn(move || s.next_spec()).collect()
        };
        let spec = TenantSpec::parse("a:2,b:1").unwrap();
        let tagged: Vec<JobSpec> = {
            let mut s =
                SyntheticSource::new(cfg(200, 9)).with_tenants(spec);
            std::iter::from_fn(move || s.next_spec()).collect()
        };
        assert_eq!(plain.len(), tagged.len());
        for (p, t) in plain.iter().zip(&tagged) {
            assert_eq!(p.id, t.id);
            assert_eq!(p.model, t.model);
            assert_eq!(p.gpus, t.gpus);
            assert_eq!(p.arrival_s, t.arrival_s);
            assert_eq!(p.duration_s, t.duration_s);
        }
        // Both tenants actually used, roughly 2:1.
        let a = tagged.iter().filter(|s| s.tenant == TenantId(0)).count();
        let b = tagged.iter().filter(|s| s.tenant == TenantId(1)).count();
        assert!(a > b, "weighted assignment: {a} vs {b}");
        assert!(b > 20, "minority tenant shouldn't starve: {b}");
    }

    #[test]
    fn len_hint_counts_down() {
        let mut s = SyntheticSource::new(cfg(3, 1));
        assert_eq!(s.len_hint(), Some(3));
        s.next_spec();
        assert_eq!(s.len_hint(), Some(2));
        while s.next_spec().is_some() {}
        assert_eq!(s.len_hint(), Some(0));
    }

    #[test]
    fn respects_split_families() {
        let mut s = SyntheticSource::new(TraceConfig {
            n_jobs: 300,
            split: crate::trace::SPLIT_WORST, // 50/0/50
            seed: 3,
            ..TraceConfig::default()
        });
        while let Some(spec) = s.next_spec() {
            assert_ne!(spec.model.task(), Task::Language);
        }
    }
}
