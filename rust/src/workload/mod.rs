//! Pluggable workload ingestion: trace sources, tenants, streaming replay.
//!
//! The paper evaluates on the Microsoft Philly trace and on
//! production-derived synthetic workloads, always in a *multi-tenant*
//! cluster. This module is the single entry point for "where jobs come
//! from":
//!
//! - [`WorkloadSource`] — the pluggable interface: a deterministic,
//!   seedable stream of timestamped [`JobSpec`]s tagged with a
//!   [`TenantId`]. Sources yield jobs in non-decreasing arrival order, so
//!   both the simulator (batch) and the deploy leader (streaming) can
//!   consume them incrementally.
//! - [`SyntheticSource`] — the Philly-marginals generator
//!   ([`crate::trace`] refactored behind the trait; byte-identical output
//!   for the same [`TraceConfig`](crate::trace::TraceConfig)).
//! - [`PhillyTraceSource`] — a Philly-format CSV reader with load-scaling
//!   and time-warp knobs (λ rescale, duration clamp, GPU-demand remap).
//! - [`AlibabaTraceSource`] — an Alibaba-style machine-utilization
//!   adapter mapping CPU/memory-heavy entries onto the big-data
//!   `Fixed`/DRF job families of §5.7.
//! - [`GoogleTraceSource`] — the 2019 Google cluster-data event format
//!   (instance events + machine events + resource multipliers),
//!   streamed line-by-line with memory bounded by *concurrent*
//!   collections — the million-job-scale ingest path.
//! - [`admission`] — weighted-quota tenant admission (GPU share per
//!   tenant with work-conserving spill), used by the coordinator ahead of
//!   the policy ordering.
//!
//! ## Tenant spec syntax
//!
//! Tenants are named on the CLI as `name:weight` pairs:
//! `--tenants a:2,b:1` gives tenant `a` twice tenant `b`'s GPU share.
//! The weight is optional (`--tenants a,b` = equal shares). For file
//! traces the names match the trace's own tenant column (Philly `vc`,
//! Alibaba `machine_id` group); unmatched trace tenants default to
//! weight 1.

pub mod admission;
mod alibaba;
mod google;
mod philly;
mod synthetic;

pub use admission::{admit, AdmissionJob, AdmissionOutcome, TenantQuotas};
pub use alibaba::{AlibabaTraceConfig, AlibabaTraceSource};
pub use google::{GoogleTraceConfig, GoogleTraceSource};
pub use philly::{PhillyTraceConfig, PhillyTraceSource};
pub use synthetic::SyntheticSource;

use crate::job::{Job, JobId, ModelKind, TenantId};

/// One job as produced by a workload source: everything the scheduler
/// needs to admit it, decoupled from the scheduler-internal [`Job`]
/// bookkeeping fields.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobSpec {
    pub id: JobId,
    pub tenant: TenantId,
    pub model: ModelKind,
    pub gpus: u32,
    /// Arrival time, seconds from trace start.
    pub arrival_s: f64,
    /// Duration under GPU-proportional allocation, seconds.
    pub duration_s: f64,
}

impl JobSpec {
    /// Convert into a scheduler [`Job`].
    pub fn into_job(self) -> Job {
        Job::new(self.id, self.model, self.gpus, self.arrival_s, self.duration_s)
            .with_tenant(self.tenant)
    }
}

/// A pluggable workload source: a deterministic stream of job specs in
/// non-decreasing arrival order. Implementations must be fully
/// reproducible from their construction parameters (seed included) —
/// every consumer in the crate relies on replaying a source twice giving
/// identical jobs.
pub trait WorkloadSource: Send {
    /// Source name for logs and reports.
    fn name(&self) -> &'static str;

    /// Next job spec, or `None` when the trace is exhausted.
    fn next_spec(&mut self) -> Option<JobSpec>;

    /// Remaining number of jobs, when known up front.
    fn len_hint(&self) -> Option<usize> {
        None
    }

    /// Human-readable tenant names, indexed by `TenantId.0`.
    fn tenant_names(&self) -> Vec<String> {
        vec!["default".to_string()]
    }

    /// Drain the source into scheduler jobs (batch consumers).
    fn drain_jobs(&mut self) -> Vec<Job> {
        let mut out = Vec::new();
        while let Some(spec) = self.next_spec() {
            out.push(spec.into_job());
        }
        out
    }
}

/// Replay an in-memory job list as a stream (sorted by arrival). Bridges
/// the batch world (`Vec<Job>`) to streaming consumers like the deploy
/// leader.
pub struct ReplaySource {
    jobs: std::vec::IntoIter<Job>,
    names: Vec<String>,
}

impl ReplaySource {
    pub fn from_jobs(mut jobs: Vec<Job>) -> ReplaySource {
        jobs.sort_by(|a, b| {
            a.arrival_s.total_cmp(&b.arrival_s).then(a.id.cmp(&b.id))
        });
        let max_tenant = jobs.iter().map(|j| j.tenant.0).max().unwrap_or(0);
        let names = (0..=max_tenant).map(|t| format!("t{t}")).collect();
        ReplaySource { jobs: jobs.into_iter(), names }
    }
}

impl WorkloadSource for ReplaySource {
    fn name(&self) -> &'static str {
        "replay"
    }

    fn next_spec(&mut self) -> Option<JobSpec> {
        self.jobs.next().map(|j| JobSpec {
            id: j.id,
            tenant: j.tenant,
            model: j.model,
            gpus: j.gpus,
            arrival_s: j.arrival_s,
            duration_s: j.duration_prop_s,
        })
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.jobs.len())
    }

    fn tenant_names(&self) -> Vec<String> {
        self.names.clone()
    }
}

/// Minimal comma-split CSV document shared by the trace readers: a
/// header row plus trimmed cells, with `#` comments and blank lines
/// skipped and 1-based line numbers preserved for error reporting.
/// Cells must not contain commas (the supported trace projections never
/// do).
pub(crate) struct CsvDoc<'a> {
    columns: Vec<&'a str>,
    rows: Vec<CsvRow<'a>>,
}

/// One data row of a [`CsvDoc`].
pub(crate) struct CsvRow<'a> {
    pub(crate) line_no: usize,
    cells: Vec<&'a str>,
}

impl<'a> CsvDoc<'a> {
    pub(crate) fn parse(text: &'a str) -> Result<CsvDoc<'a>, String> {
        let mut lines = text
            .lines()
            .enumerate()
            .map(|(i, l)| (i + 1, l.trim()))
            .filter(|(_, l)| !l.is_empty() && !l.starts_with('#'));
        let (_, header) =
            lines.next().ok_or_else(|| "empty trace file".to_string())?;
        let columns = header.split(',').map(str::trim).collect();
        let rows = lines
            .map(|(line_no, l)| CsvRow {
                line_no,
                cells: l.split(',').map(str::trim).collect(),
            })
            .collect();
        Ok(CsvDoc { columns, rows })
    }

    pub(crate) fn column(&self, name: &str) -> Option<usize> {
        self.columns.iter().position(|c| *c == name)
    }

    pub(crate) fn require_column(&self, name: &str) -> Result<usize, String> {
        self.column(name)
            .ok_or_else(|| format!("missing column '{name}'"))
    }

    pub(crate) fn rows(&self) -> &[CsvRow<'a>] {
        &self.rows
    }
}

impl<'a> CsvRow<'a> {
    pub(crate) fn cell(&self, idx: usize) -> Result<&'a str, String> {
        self.cells.get(idx).copied().ok_or_else(|| {
            format!("line {}: too few columns", self.line_no)
        })
    }

    /// Parse cell `idx` as `T`, reporting `name` on failure.
    pub(crate) fn parse<T: std::str::FromStr>(
        &self,
        idx: usize,
        name: &str,
    ) -> Result<T, String> {
        self.cell(idx)?
            .parse()
            .map_err(|_| format!("line {}: bad {name}", self.line_no))
    }
}

/// First-appearance tenant-name interner shared by the trace readers.
pub(crate) struct TenantInterner {
    ids: std::collections::BTreeMap<String, TenantId>,
    names: Vec<String>,
}

impl TenantInterner {
    pub(crate) fn new() -> TenantInterner {
        TenantInterner { ids: std::collections::BTreeMap::new(), names: Vec::new() }
    }

    /// The id of `name`, allocating the next dense id on first sight.
    pub(crate) fn intern(&mut self, name: &str) -> TenantId {
        match self.ids.get(name) {
            Some(&t) => t,
            None => {
                let t = TenantId(self.names.len() as u32);
                self.ids.insert(name.to_string(), t);
                self.names.push(name.to_string());
                t
            }
        }
    }

    /// Interned names in id order; a lone `default` if nothing interned.
    pub(crate) fn into_names(mut self) -> Vec<String> {
        if self.names.is_empty() {
            self.names.push("default".to_string());
        }
        self.names
    }
}

/// A raw trace row before normalization: (timestamp, tenant, model,
/// gpus, duration_s).
pub(crate) type RawRow = (f64, TenantId, ModelKind, u32, f64);

/// Shared reader epilogue: re-base timestamps to the earliest row, apply
/// the λ rescale, sort by arrival, and assign dense [`JobId`]s.
pub(crate) fn finalize_rows(rows: Vec<RawRow>, load_scale: f64) -> Vec<JobSpec> {
    let t0 = rows.iter().map(|r| r.0).fold(f64::INFINITY, f64::min);
    let mut specs: Vec<JobSpec> = rows
        .into_iter()
        .map(|(ts, tenant, model, gpus, duration_s)| JobSpec {
            id: JobId(0), // assigned after sorting
            tenant,
            model,
            gpus,
            arrival_s: (ts - t0) / load_scale,
            duration_s,
        })
        .collect();
    specs.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    for (i, s) in specs.iter_mut().enumerate() {
        s.id = JobId(i as u64);
    }
    specs
}

/// Parsed `--tenants` CLI spec: ordered names with weights.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub names: Vec<String>,
    pub weights: Vec<f64>,
}

impl TenantSpec {
    /// Parse `"a:2,b:1"` / `"a,b"` (missing weight = 1). Errors on empty
    /// specs, duplicate names, and non-positive weights.
    pub fn parse(s: &str) -> Result<TenantSpec, String> {
        let mut names = Vec::new();
        let mut weights = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, weight) = match part.split_once(':') {
                Some((n, w)) => {
                    let w: f64 = w.trim().parse().map_err(|_| {
                        format!("bad tenant weight in '{part}'")
                    })?;
                    (n.trim().to_string(), w)
                }
                None => (part.to_string(), 1.0),
            };
            if !(weight > 0.0) {
                return Err(format!(
                    "tenant '{name}' weight must be positive"
                ));
            }
            if names.contains(&name) {
                return Err(format!("duplicate tenant '{name}'"));
            }
            names.push(name);
            weights.push(weight);
        }
        if names.is_empty() {
            return Err("empty tenant spec".to_string());
        }
        Ok(TenantSpec { names, weights })
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// The canonical `name:weight,...` string this spec parses back from
    /// (`TenantSpec::parse(&spec.canonical()) == spec`).
    pub fn canonical(&self) -> String {
        self.names
            .iter()
            .zip(&self.weights)
            .map(|(n, w)| format!("{n}:{w}"))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// The weight of `name`, if it is in the spec.
    pub fn weight_of(&self, name: &str) -> Option<f64> {
        self.names
            .iter()
            .position(|n| n == name)
            .map(|i| self.weights[i])
    }

    /// Quotas keyed by this spec's own positional tenant ids (used with
    /// [`SyntheticSource::with_tenants`]).
    pub fn quotas(&self) -> TenantQuotas {
        let mut q = TenantQuotas::new();
        for (i, w) in self.weights.iter().enumerate() {
            q.set(TenantId(i as u32), *w);
        }
        q
    }

    /// Quotas for a trace whose tenants are `trace_names` (positional
    /// [`TenantId`]s): spec names are matched by string, unmatched trace
    /// tenants keep the default weight 1.
    pub fn quotas_for(&self, trace_names: &[String]) -> TenantQuotas {
        let mut q = TenantQuotas::new();
        for (i, name) in trace_names.iter().enumerate() {
            if let Some(w) = self.weight_of(name) {
                q.set(TenantId(i as u32), w);
            }
        }
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tenant_spec_parses_weights_and_defaults() {
        let spec = TenantSpec::parse("a:2,b:1,c").unwrap();
        assert_eq!(spec.names, vec!["a", "b", "c"]);
        assert_eq!(spec.weights, vec![2.0, 1.0, 1.0]);
        assert_eq!(spec.weight_of("a"), Some(2.0));
        assert_eq!(spec.weight_of("z"), None);
    }

    #[test]
    fn tenant_spec_rejects_garbage() {
        assert!(TenantSpec::parse("").is_err());
        assert!(TenantSpec::parse("a:x").is_err());
        assert!(TenantSpec::parse("a:0").is_err());
        assert!(TenantSpec::parse("a:-1").is_err());
        assert!(TenantSpec::parse("a,a").is_err());
    }

    #[test]
    fn tenant_spec_canonical_roundtrip() {
        for s in ["a:2,b:1", "x:0.5,y:3,z:1", "solo:1"] {
            let spec = TenantSpec::parse(s).unwrap();
            assert_eq!(TenantSpec::parse(&spec.canonical()).unwrap(), spec);
        }
    }

    #[test]
    fn tenant_spec_quotas_positional() {
        let spec = TenantSpec::parse("a:3,b:1").unwrap();
        let q = spec.quotas();
        assert_eq!(q.weight(TenantId(0)), 3.0);
        assert_eq!(q.weight(TenantId(1)), 1.0);
        // Unspecified tenants fall back to 1.0.
        assert_eq!(q.weight(TenantId(9)), 1.0);
    }

    #[test]
    fn quotas_for_matches_by_name() {
        let spec = TenantSpec::parse("vc2:4").unwrap();
        let trace_names =
            vec!["vc1".to_string(), "vc2".to_string()];
        let q = spec.quotas_for(&trace_names);
        assert_eq!(q.weight(TenantId(0)), 1.0); // vc1 unmatched
        assert_eq!(q.weight(TenantId(1)), 4.0); // vc2 matched
    }

    #[test]
    fn replay_source_sorts_and_streams() {
        use crate::job::ModelKind;
        let jobs = vec![
            Job::new(JobId(1), ModelKind::Lstm, 1, 50.0, 60.0),
            Job::new(JobId(0), ModelKind::Lstm, 2, 10.0, 60.0)
                .with_tenant(TenantId(1)),
        ];
        let mut src = ReplaySource::from_jobs(jobs);
        assert_eq!(src.len_hint(), Some(2));
        let a = src.next_spec().unwrap();
        assert_eq!(a.id, JobId(0));
        assert_eq!(a.tenant, TenantId(1));
        assert_eq!(a.gpus, 2);
        let b = src.next_spec().unwrap();
        assert_eq!(b.arrival_s, 50.0);
        assert!(src.next_spec().is_none());
        assert_eq!(src.len_hint(), Some(0));
    }
}
