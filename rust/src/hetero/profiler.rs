//! Optimistic profiling along the additional machine-type dimension
//! (paper A.2: "profiling CPU and memory requirements along an
//! additional dimension — GPU type, at an additional profiling cost").
//!
//! The adaptive CPU sweep + analytic memory fill of the homogeneous
//! profiler ([`crate::profiler`]) runs once per generation, producing a
//! 3-D sensitivity structure `W_ij[c, m]` — one
//! [`SensitivityMatrix`] per type. The profiling cost therefore scales
//! with `|K|`, exactly the trade-off the appendix calls out.

use super::cluster::HeteroCluster;
use super::gen::GpuGen;
use super::perf::HeteroPerfModel;
use crate::job::Job;
use crate::profiler::{
    adaptive_cpu_sweep, analytic_memory_fill, interp, mem_grid,
    SensitivityMatrix, MINUTES_PER_POINT,
};
use crate::util::rng::Pcg64;

/// Per-type sensitivity matrices for one job (`W_ij`, A.2.1).
#[derive(Debug, Clone)]
pub struct HeteroSensitivity {
    /// `(generation, matrix)` pairs, one per machine type in the cluster.
    pub per_type: Vec<(GpuGen, SensitivityMatrix)>,
    /// Total empirical points across all types.
    pub empirical_points: usize,
    /// Estimated profiling wall-clock cost, minutes.
    pub cost_minutes: f64,
}

impl HeteroSensitivity {
    pub fn matrix(&self, gen: GpuGen) -> Option<&SensitivityMatrix> {
        self.per_type.iter().find(|(g, _)| *g == gen).map(|(_, m)| m)
    }

    /// The conservative fairness oracle `W_j^Fair` (A.2.2): the
    /// GPU-proportional throughput on the slowest generation profiled.
    pub fn fair_throughput(&self) -> f64 {
        let gens: Vec<GpuGen> =
            self.per_type.iter().map(|(g, _)| *g).collect();
        let slowest = GpuGen::slowest(&gens);
        self.matrix(slowest)
            .map(|m| m.proportional_throughput())
            .unwrap_or(0.0)
    }
}

/// The heterogeneous optimistic profiler.
#[derive(Debug, Clone)]
pub struct HeteroProfiler {
    /// Ground truth per machine type.
    pub worlds: Vec<HeteroPerfModel>,
    pub noise_sd: f64,
    pub threshold: f64,
}

impl HeteroProfiler {
    /// Profiler for every type group in `cluster`.
    pub fn for_cluster(cluster: &HeteroCluster) -> HeteroProfiler {
        HeteroProfiler {
            worlds: cluster
                .groups
                .iter()
                .map(|g| HeteroPerfModel::new(g.cluster.spec, g.gen))
                .collect(),
            noise_sd: 0.03,
            threshold: 0.10,
        }
    }

    pub fn noiseless(cluster: &HeteroCluster) -> HeteroProfiler {
        HeteroProfiler { noise_sd: 0.0, ..HeteroProfiler::for_cluster(cluster) }
    }

    /// Profile `job` on every machine type (A.2's `W_ij`).
    pub fn profile(&self, job: &Job) -> HeteroSensitivity {
        let mut per_type = Vec::with_capacity(self.worlds.len());
        let mut points = 0usize;
        for world in &self.worlds {
            let spec = world.base.spec;
            let span = ((job.gpus + spec.gpus - 1) / spec.gpus).max(1) as usize;
            let max_cpus = spec.cpus as usize * span;
            let max_mem = spec.mem_gb * span as f64;
            // Distinct deterministic noise stream per (job, type).
            let mut rng = Pcg64::new(
                0x5EED_4E7E ^ job.rng_stream,
                job.rng_stream ^ world.gen as u64,
            );
            let full_mem = max_mem;
            let (pts, n) = adaptive_cpu_sweep(max_cpus, self.threshold, |c| {
                let t = world.throughput(
                    job.model,
                    job.gpus,
                    c as f64,
                    full_mem,
                );
                if self.noise_sd == 0.0 {
                    t
                } else {
                    (t * (1.0 + self.noise_sd * rng.normal())).max(0.0)
                }
            });
            points += n;
            let cpu_curve: Vec<f64> =
                (0..=max_cpus).map(|c| interp(&pts, c as f64)).collect();
            let mem_points = mem_grid(max_mem);
            let cpu_points: Vec<f64> =
                (1..=max_cpus).map(|c| c as f64).collect();
            let tput = analytic_memory_fill(
                job.model,
                job.gpus,
                &cpu_curve,
                &mem_points,
            );
            let prop_c =
                spec.cpus as f64 / spec.gpus as f64 * job.gpus as f64;
            let prop_m = spec.mem_gb / spec.gpus as f64 * job.gpus as f64;
            per_type.push((
                world.gen,
                SensitivityMatrix::new(
                    job.model, job.gpus, cpu_points, mem_points, tput,
                    prop_c, prop_m,
                ),
            ));
        }
        HeteroSensitivity {
            per_type,
            empirical_points: points,
            cost_minutes: points as f64 * MINUTES_PER_POINT,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{JobId, ModelKind};

    fn cluster() -> HeteroCluster {
        HeteroCluster::two_tier(2)
    }

    fn job(model: ModelKind) -> Job {
        Job::new(JobId(3), model, 1, 0.0, 3600.0)
    }

    #[test]
    fn profiles_every_type() {
        let p = HeteroProfiler::noiseless(&cluster());
        let s = p.profile(&job(ModelKind::ResNet18));
        assert_eq!(s.per_type.len(), 2);
        assert!(s.matrix(GpuGen::P100).is_some());
        assert!(s.matrix(GpuGen::V100).is_some());
        assert!(s.matrix(GpuGen::A100).is_none());
    }

    #[test]
    fn per_type_matrices_reflect_generation_speed() {
        let p = HeteroProfiler::noiseless(&cluster());
        let s = p.profile(&job(ModelKind::Gnmt)); // compute-bound
        let slow = s.matrix(GpuGen::P100).unwrap().max_throughput();
        let fast = s.matrix(GpuGen::V100).unwrap().max_throughput();
        assert!(
            fast / slow > 1.5,
            "compute-bound job must be faster on V100: {slow} vs {fast}"
        );
    }

    #[test]
    fn cost_scales_with_type_count() {
        let two = HeteroProfiler::noiseless(&cluster());
        let j = job(ModelKind::AlexNet);
        let s2 = two.profile(&j);
        let one = HeteroProfiler {
            worlds: two.worlds[..1].to_vec(),
            ..two.clone()
        };
        let s1 = one.profile(&j);
        assert!(
            s2.cost_minutes > s1.cost_minutes,
            "profiling 2 types must cost more than 1"
        );
    }

    #[test]
    fn fair_oracle_is_slowest_type_proportional() {
        let p = HeteroProfiler::noiseless(&cluster());
        let s = p.profile(&job(ModelKind::Gnmt));
        let fair = s.fair_throughput();
        let p100 = s.matrix(GpuGen::P100).unwrap().proportional_throughput();
        assert_eq!(fair, p100);
        // Any type's proportional throughput dominates the oracle.
        for (_, m) in &s.per_type {
            assert!(m.proportional_throughput() + 1e-9 >= fair);
        }
    }

    #[test]
    fn deterministic_given_job_stream() {
        let p = HeteroProfiler::for_cluster(&cluster());
        let j = job(ModelKind::MobileNetV2);
        let a = p.profile(&j);
        let b = p.profile(&j);
        assert_eq!(a.empirical_points, b.empirical_points);
        for ((ga, ma), (gb, mb)) in a.per_type.iter().zip(&b.per_type) {
            assert_eq!(ga, gb);
            assert_eq!(ma.tput, mb.tput);
        }
    }
}
