//! Heterogeneous cluster: a set of homogeneous type-groups (paper A.2.1).
//!
//! Each *type group* is `s_i` identical machines of generation `i`,
//! modeled as one [`Cluster`] so all the homogeneous bookkeeping
//! (allocation invariants, consistency checks, proportional shares)
//! carries over. The paper's per-round constraint that a job never spans
//! two types (A.2.2) is enforced by construction: placements live inside
//! a single group's `Cluster`.

use super::gen::GpuGen;
use crate::cluster::{Cluster, ServerSpec};
use crate::job::JobId;

/// Specification of one machine type: generation + per-machine resources
/// + machine count (`s_i`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TypeSpec {
    pub gen: GpuGen,
    pub spec: ServerSpec,
    pub machines: usize,
}

/// One homogeneous group inside a heterogeneous cluster.
#[derive(Debug, Clone)]
pub struct TypeGroup {
    pub gen: GpuGen,
    pub cluster: Cluster,
}

/// A heterogeneous cluster: disjoint homogeneous type groups.
#[derive(Debug, Clone)]
pub struct HeteroCluster {
    pub groups: Vec<TypeGroup>,
}

impl HeteroCluster {
    /// Build from type specifications. Types must be distinct.
    pub fn new(types: &[TypeSpec]) -> HeteroCluster {
        for (i, a) in types.iter().enumerate() {
            for b in &types[i + 1..] {
                assert_ne!(a.gen, b.gen, "duplicate machine type {:?}", a.gen);
            }
        }
        HeteroCluster {
            groups: types
                .iter()
                .map(|t| TypeGroup {
                    gen: t.gen,
                    cluster: Cluster::homogeneous(t.spec, t.machines),
                })
                .collect(),
        }
    }

    /// The standard two-type evaluation cluster: half V100 machines, half
    /// P100 machines of the paper's server shape.
    pub fn two_tier(machines_per_type: usize) -> HeteroCluster {
        let spec = ServerSpec::default();
        HeteroCluster::new(&[
            TypeSpec { gen: GpuGen::P100, spec, machines: machines_per_type },
            TypeSpec { gen: GpuGen::V100, spec, machines: machines_per_type },
        ])
    }

    pub fn gens(&self) -> Vec<GpuGen> {
        self.groups.iter().map(|g| g.gen).collect()
    }

    pub fn group(&self, gen: GpuGen) -> Option<&TypeGroup> {
        self.groups.iter().find(|g| g.gen == gen)
    }

    pub fn group_mut(&mut self, gen: GpuGen) -> Option<&mut TypeGroup> {
        self.groups.iter_mut().find(|g| g.gen == gen)
    }

    /// Total GPUs across all types (`G`, A.2.1).
    pub fn total_gpus(&self) -> u32 {
        self.groups.iter().map(|g| g.cluster.total_gpus()).sum()
    }

    pub fn free_gpus(&self) -> u32 {
        self.groups.iter().map(|g| g.cluster.free_gpus()).sum()
    }

    pub fn total_cpus(&self) -> f64 {
        self.groups.iter().map(|g| g.cluster.total_cpus()).sum()
    }

    pub fn free_cpus(&self) -> f64 {
        self.groups.iter().map(|g| g.cluster.free_cpus()).sum()
    }

    pub fn total_mem_gb(&self) -> f64 {
        self.groups.iter().map(|g| g.cluster.total_mem_gb()).sum()
    }

    pub fn free_mem_gb(&self) -> f64 {
        self.groups.iter().map(|g| g.cluster.free_mem_gb()).sum()
    }

    /// Which group hosts `job`, if placed.
    pub fn host_gen(&self, job: JobId) -> Option<GpuGen> {
        self.groups
            .iter()
            .find(|g| g.cluster.placement(job).is_some())
            .map(|g| g.gen)
    }

    /// Evict every placement in every group (round reset, §3.2).
    pub fn evict_all(&mut self) {
        for g in &mut self.groups {
            g.cluster.evict_all();
        }
    }

    /// Aggregate GPU utilization in [0, 1].
    pub fn gpu_utilization(&self) -> f64 {
        1.0 - self.free_gpus() as f64 / self.total_gpus() as f64
    }

    /// Aggregate CPU allocation fraction in [0, 1].
    pub fn cpu_utilization(&self) -> f64 {
        1.0 - self.free_cpus() / self.total_cpus()
    }

    /// Consistency check across every group.
    pub fn check_consistency(&self) -> Result<(), String> {
        for g in &self.groups {
            g.cluster
                .check_consistency()
                .map_err(|e| format!("{:?}: {e}", g.gen))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Placement, Share};

    #[test]
    fn two_tier_capacity() {
        let c = HeteroCluster::two_tier(2);
        assert_eq!(c.groups.len(), 2);
        assert_eq!(c.total_gpus(), 32);
        assert_eq!(c.total_cpus(), 96.0);
        assert_eq!(c.free_gpus(), 32);
        assert!(c.check_consistency().is_ok());
    }

    #[test]
    fn groups_are_independent() {
        let mut c = HeteroCluster::two_tier(1);
        let share = Share { gpus: 4, cpus: 12.0, mem_gb: 250.0 };
        c.group_mut(GpuGen::V100)
            .unwrap()
            .cluster
            .place(JobId(1), Placement::single(0, share));
        assert_eq!(c.host_gen(JobId(1)), Some(GpuGen::V100));
        assert_eq!(c.group(GpuGen::P100).unwrap().cluster.free_gpus(), 8);
        assert_eq!(c.free_gpus(), 12);
        c.evict_all();
        assert_eq!(c.free_gpus(), 16);
        assert_eq!(c.host_gen(JobId(1)), None);
    }

    #[test]
    #[should_panic(expected = "duplicate machine type")]
    fn duplicate_types_panic() {
        let spec = ServerSpec::default();
        HeteroCluster::new(&[
            TypeSpec { gen: GpuGen::V100, spec, machines: 1 },
            TypeSpec { gen: GpuGen::V100, spec, machines: 1 },
        ]);
    }

    #[test]
    fn utilization_tracks_placements() {
        let mut c = HeteroCluster::two_tier(1);
        assert_eq!(c.gpu_utilization(), 0.0);
        c.group_mut(GpuGen::P100).unwrap().cluster.place(
            JobId(2),
            Placement::single(0, Share { gpus: 8, cpus: 24.0, mem_gb: 500.0 }),
        );
        assert_eq!(c.gpu_utilization(), 0.5);
    }
}
