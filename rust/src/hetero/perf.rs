//! Ground-truth throughput on a heterogeneous cluster.
//!
//! Identical to the homogeneous pipeline model ([`crate::perf`]) except
//! the GPU stage rate is scaled by the machine type's generation factor
//! (`W_ij`, paper A.2.1). CPU pre-processing and storage fetch are
//! host-side and do not change with GPU generation.

use super::gen::GpuGen;
use crate::cluster::ServerSpec;
use crate::job::ModelKind;
use crate::perf::{PerfModel, STORAGE_BW_MB_PER_GPU};

/// Ground truth for one machine type (generation + server shape).
#[derive(Debug, Clone, Copy)]
pub struct HeteroPerfModel {
    pub base: PerfModel,
    pub gen: GpuGen,
}

impl HeteroPerfModel {
    pub fn new(spec: ServerSpec, gen: GpuGen) -> HeteroPerfModel {
        HeteroPerfModel { base: PerfModel::new(spec), gen }
    }

    /// Steady-state throughput of `model` on `gpus` GPUs of this
    /// generation with `cpus` cores and `mem_gb` GB of cache:
    /// `min(scale_i · g · gpu_tput, c · prep_rate, fetch_rate)`.
    pub fn throughput(
        &self,
        model: ModelKind,
        gpus: u32,
        cpus: f64,
        mem_gb: f64,
    ) -> f64 {
        let co = model.coeffs();
        if mem_gb < co.min_mem_gb {
            return 0.0;
        }
        let scale = self.gen.compute_scale(model.task());
        let gpu_rate = gpus as f64 * co.gpu_tput * scale;
        let cpu_rate = cpus * co.cpu_prep_rate;
        let fetch_rate = {
            let cache = crate::perf::cache::MinIoCache::new(
                co.dataset_gb,
                mem_gb - co.min_mem_gb,
            );
            let miss = cache.miss_fraction();
            if miss <= 0.0 {
                f64::INFINITY
            } else {
                STORAGE_BW_MB_PER_GPU * 1024.0 * gpus as f64
                    / (miss * co.sample_kb)
            }
        };
        gpu_rate.min(cpu_rate).min(fetch_rate)
    }

    /// Throughput at this type's GPU-proportional share (the per-type
    /// fairness reference `W_ij[C_g, M_g]`).
    pub fn proportional_throughput(&self, model: ModelKind, gpus: u32) -> f64 {
        let spec = self.base.spec;
        let c = spec.cpus as f64 / spec.gpus as f64 * gpus as f64;
        let m = spec.mem_gb / spec.gpus as f64 * gpus as f64;
        self.throughput(model, gpus, c, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::ModelKind::*;

    fn model_on(gen: GpuGen) -> HeteroPerfModel {
        HeteroPerfModel::new(ServerSpec::default(), gen)
    }

    #[test]
    fn v100_matches_homogeneous_ground_truth() {
        let het = model_on(GpuGen::V100);
        let hom = PerfModel::new(ServerSpec::default());
        for m in crate::job::ALL_MODELS {
            for (c, mem) in [(3.0, 62.5), (12.0, 500.0), (1.0, 30.0)] {
                assert_eq!(
                    het.throughput(m, 1, c, mem),
                    hom.throughput(m, 1, c, mem),
                    "{m:?} at ({c}, {mem})"
                );
            }
        }
    }

    #[test]
    fn faster_generation_never_slower() {
        for m in crate::job::ALL_MODELS {
            for (c, mem) in [(3.0, 62.5), (24.0, 500.0)] {
                let k80 = model_on(GpuGen::K80).throughput(m, 1, c, mem);
                let v100 = model_on(GpuGen::V100).throughput(m, 1, c, mem);
                let a100 = model_on(GpuGen::A100).throughput(m, 1, c, mem);
                assert!(k80 <= v100 && v100 <= a100, "{m:?} ({c},{mem})");
            }
        }
    }

    #[test]
    fn input_bound_jobs_gain_little_from_faster_gpus() {
        // ShuffleNet at 3 CPUs is CPU-bound: generation barely matters.
        let lo = model_on(GpuGen::K80).throughput(ShuffleNetV2, 1, 3.0, 500.0);
        let hi = model_on(GpuGen::A100).throughput(ShuffleNetV2, 1, 3.0, 500.0);
        assert!(
            hi / lo < 1.05,
            "input-bound job should not scale with GPU gen: {lo} -> {hi}"
        );
        // ...while a compute-bound language model scales with generation.
        let lo = model_on(GpuGen::K80).throughput(Gnmt, 1, 3.0, 62.5);
        let hi = model_on(GpuGen::A100).throughput(Gnmt, 1, 3.0, 62.5);
        assert!(hi / lo > 5.0, "compute-bound job must scale: {lo} -> {hi}");
    }

    #[test]
    fn below_working_set_is_zero_on_all_gens() {
        for gen in super::super::gen::ALL_GENS {
            assert_eq!(model_on(gen).throughput(Gnmt, 1, 3.0, 10.0), 0.0);
        }
    }
}
