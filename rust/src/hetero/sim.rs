//! Heterogeneous trace simulation: the second configuration of the
//! shared event-driven core ([`crate::sim`]).
//!
//! [`HeteroSimulator`] wires a [`HeteroCluster`], the per-type profiler
//! (A.2), per-generation ground truths, and a [`HetMechanism`] into a
//! [`HeteroModel`] and hands the loop to [`run_events`] — the *same*
//! loop the homogeneous engine runs, so policy ordering, tenant-quota
//! admission with work-conserving spill, streaming workload sources,
//! progress accounting, and utilization metrics are shared code, not a
//! fork. Progress accrues at the *granted* throughput on the *assigned
//! type* — a job bounced between generations across rounds advances at
//! whatever each round's hardware actually delivers.
//!
//! Work accounting: a job's `total_samples` is derived from its trace
//! duration under the fairness oracle's throughput (`W_j^Fair`,
//! slowest-type proportional), making "duration" hardware-meaningful in
//! the heterogeneous setting too. On a single-type V100 cluster the
//! oracle coincides with the homogeneous proportional baseline, and the
//! whole engine reproduces the homogeneous schedule bit-for-bit
//! (`tests/scenarios.rs`).

use super::cluster::HeteroCluster;
use super::gen::GpuGen;
use super::mechanism::{het_by_name, HetJobRequest, HetMechanism};
use super::perf::HeteroPerfModel;
use super::profiler::{HeteroProfiler, HeteroSensitivity};
use crate::cluster::ServerSpec;
use crate::hetero::TypeSpec;
use crate::job::{Job, JobId, TenantId};
use crate::metrics::{per_tenant_stats, JctStats, UtilSample, UtilizationLog};
use crate::policy::{by_name as policy_by_name, PolicyJobView};
use crate::sim::{
    run_events, utilization_sample, ClusterModel, CoreConfig, FinishedJob,
    SimResult,
};
use crate::workload::TenantQuotas;
use std::collections::BTreeMap;

/// Heterogeneous simulator configuration.
pub struct HeteroSimConfig {
    pub types: Vec<TypeSpec>,
    pub round_s: f64,
    pub policy: String,
    pub mechanism: String,
    pub profile_noise: f64,
    pub max_sim_s: f64,
}

impl Default for HeteroSimConfig {
    fn default() -> Self {
        let spec = ServerSpec::default();
        HeteroSimConfig {
            types: vec![
                TypeSpec {
                    gen: super::GpuGen::P100,
                    spec,
                    machines: 8,
                },
                TypeSpec {
                    gen: super::GpuGen::V100,
                    spec,
                    machines: 8,
                },
            ],
            round_s: 300.0,
            policy: "srtf".into(),
            mechanism: "het-tune".into(),
            profile_noise: 0.0,
            max_sim_s: 400.0 * 24.0 * 3600.0,
        }
    }
}

/// Simulation output.
#[derive(Debug)]
pub struct HeteroSimResult {
    /// (job id, jct seconds) in completion order.
    pub jcts: Vec<(JobId, f64)>,
    pub makespan_s: f64,
    pub rounds: usize,
    pub profiling_minutes: f64,
    /// Full per-job records (tenant-tagged), from the shared core.
    pub finished: Vec<FinishedJob>,
    /// Per-round utilization samples (shared-core accounting).
    pub utilization: UtilizationLog,
}

impl HeteroSimResult {
    fn from_result(r: SimResult) -> HeteroSimResult {
        HeteroSimResult {
            jcts: r.finished.iter().map(|f| (f.id, f.jct_s)).collect(),
            makespan_s: r.makespan_s,
            rounds: r.rounds,
            profiling_minutes: r.profiling_minutes,
            finished: r.finished,
            utilization: r.utilization,
        }
    }

    pub fn jct_stats(&self) -> JctStats {
        let v: Vec<f64> = self.jcts.iter().map(|&(_, j)| j).collect();
        JctStats::from_jcts(&v)
    }

    /// Per-tenant JCT summaries (multi-tenant workloads).
    pub fn tenant_stats(&self) -> BTreeMap<TenantId, JctStats> {
        let pairs: Vec<(TenantId, f64)> =
            self.finished.iter().map(|f| (f.tenant, f.jct_s)).collect();
        per_tenant_stats(&pairs)
    }
}

/// The heterogeneous topology behind the shared core: disjoint type
/// groups, per-generation ground truths, per-type sensitivity matrices,
/// and a [`HetMechanism`].
pub struct HeteroModel {
    cluster: HeteroCluster,
    worlds: BTreeMap<GpuGen, HeteroPerfModel>,
    profiler: HeteroProfiler,
    mechanism: Box<dyn HetMechanism>,
    sens: BTreeMap<JobId, HeteroSensitivity>,
    /// Largest single type group, GPUs — the gang-fit bound (A.2.2: no
    /// cross-type spans).
    max_group_gpus: u32,
}

impl HeteroModel {
    /// Build the model a [`HeteroSimConfig`] describes.
    pub fn from_config(cfg: &HeteroSimConfig) -> HeteroModel {
        let cluster = HeteroCluster::new(&cfg.types);
        let worlds: BTreeMap<GpuGen, HeteroPerfModel> = cluster
            .groups
            .iter()
            .map(|g| (g.gen, HeteroPerfModel::new(g.cluster.spec, g.gen)))
            .collect();
        let profiler = {
            let mut p = HeteroProfiler::for_cluster(&cluster);
            p.noise_sd = cfg.profile_noise;
            p
        };
        let mechanism: Box<dyn HetMechanism> = het_by_name(&cfg.mechanism)
            .unwrap_or_else(|| {
                panic!("unknown het mechanism {}", cfg.mechanism)
            });
        let max_group_gpus = cluster
            .groups
            .iter()
            .map(|g| g.cluster.total_gpus())
            .max()
            .unwrap_or(0);
        HeteroModel {
            cluster,
            worlds,
            profiler,
            mechanism,
            sens: BTreeMap::new(),
            max_group_gpus,
        }
    }
}

impl ClusterModel for HeteroModel {
    fn fits(&self, job: &Job) -> bool {
        job.gpus <= self.max_group_gpus
    }

    fn total_gpus(&self) -> u32 {
        self.cluster.total_gpus()
    }

    fn profile_arrival(&mut self, job: &mut Job) -> f64 {
        // Profiled on every machine type (A.2's `W_ij`).
        let s = self.profiler.profile(job);
        job.total_samples = job.duration_prop_s * s.fair_throughput();
        let cost = s.cost_minutes;
        self.sens.insert(job.id, s);
        cost
    }

    fn forget(&mut self, id: JobId) {
        self.sens.remove(&id);
    }

    fn begin_round(&mut self) {
        self.cluster.evict_all();
    }

    fn policy_views(&self, active: &BTreeMap<JobId, Job>) -> Vec<PolicyJobView> {
        let total_gpus = self.cluster.total_gpus();
        let total_cpus = self.cluster.total_cpus();
        let total_mem = self.cluster.total_mem_gb();
        active
            .values()
            .map(|j| {
                let s = &self.sens[&j.id];
                let fair = s.fair_throughput();
                let remaining_est_s = if fair > 0.0 {
                    j.remaining_samples() / fair
                } else {
                    f64::INFINITY
                };
                PolicyJobView {
                    id: j.id,
                    arrival_s: j.arrival_s,
                    attained_service_s: j.attained_service_s,
                    remaining_est_s,
                    duration_prop_s: j.duration_prop_s,
                    gpus: j.gpus,
                    dominant_share: j.gpus as f64 / total_gpus as f64,
                    alignment: (j.gpus as f64 * total_gpus as f64)
                        / (total_cpus * total_mem).max(1.0),
                }
            })
            .collect()
    }

    fn place_round(
        &mut self,
        runnable: &[JobId],
        active: &BTreeMap<JobId, Job>,
    ) -> BTreeMap<JobId, f64> {
        let requests: Vec<HetJobRequest<'_>> = runnable
            .iter()
            .map(|id| HetJobRequest {
                id: *id,
                gpus: active[id].gpus,
                sens: &self.sens[id],
            })
            .collect();
        let grants = self.mechanism.allocate(&mut self.cluster, &requests);
        debug_assert!(self.cluster.check_consistency().is_ok());
        // Deploy: progress rates from the assigned type's ground truth at
        // the granted allocation.
        grants
            .iter()
            .map(|(id, g)| {
                let job = &active[id];
                let rate = self.worlds[&g.gen].throughput(
                    job.model,
                    job.gpus,
                    g.grant.demand.cpus,
                    g.grant.demand.mem_gb,
                );
                (*id, rate)
            })
            .collect()
    }

    fn utilization(&self, now: f64, active: &BTreeMap<JobId, Job>) -> UtilSample {
        utilization_sample(
            now,
            active,
            self.cluster.gpu_utilization(),
            self.cluster.cpu_utilization(),
            1.0 - self.cluster.free_mem_gb() / self.cluster.total_mem_gb(),
            self.cluster.total_cpus(),
        )
    }
}

/// The heterogeneous simulator.
pub struct HeteroSimulator {
    cfg: HeteroSimConfig,
    quotas: Option<TenantQuotas>,
}

impl HeteroSimulator {
    pub fn new(cfg: HeteroSimConfig) -> HeteroSimulator {
        HeteroSimulator { cfg, quotas: None }
    }

    /// A heterogeneous simulator whose admission enforces tenant GPU
    /// quotas (the same weighted-quota + work-conserving-spill admission
    /// as the homogeneous engine, via the shared core).
    pub fn with_quotas(
        cfg: HeteroSimConfig,
        quotas: Option<TenantQuotas>,
    ) -> HeteroSimulator {
        let mut sim = HeteroSimulator::new(cfg);
        sim.quotas = quotas;
        sim
    }

    /// Run a trace to completion (or `max_sim_s`) through the shared
    /// event-driven core.
    pub fn run(&self, jobs: Vec<Job>) -> HeteroSimResult {
        let policy = policy_by_name(&self.cfg.policy)
            .unwrap_or_else(|| panic!("unknown policy {}", self.cfg.policy));
        let mut model = HeteroModel::from_config(&self.cfg);
        let r = run_events(
            &mut model,
            policy.as_ref(),
            self.quotas.as_ref(),
            &CoreConfig {
                round_s: self.cfg.round_s,
                max_sim_s: self.cfg.max_sim_s,
            },
            jobs,
        );
        HeteroSimResult::from_result(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{generate, Split, TraceConfig};

    fn trace(n: usize, seed: u64) -> Vec<Job> {
        generate(&TraceConfig {
            n_jobs: n,
            split: Split::new(40, 40, 20),
            multi_gpu: false,
            jobs_per_hour: None,
            seed,
        })
    }

    fn run(mechanism: &str, jobs: Vec<Job>) -> HeteroSimResult {
        let sim = HeteroSimulator::new(HeteroSimConfig {
            mechanism: mechanism.into(),
            policy: "fifo".into(),
            ..Default::default()
        });
        sim.run(jobs)
    }

    #[test]
    fn all_jobs_finish() {
        let r = run("het-tune", trace(40, 7));
        assert_eq!(r.jcts.len(), 40);
        assert!(r.rounds > 0);
        assert!(r.jcts.iter().all(|&(_, j)| j > 0.0 && j.is_finite()));
    }

    #[test]
    fn het_tune_beats_type_blind_proportional() {
        let jobs = trace(60, 21);
        let tune = run("het-tune", jobs.clone());
        let prop = run("het-proportional", jobs);
        assert_eq!(tune.jcts.len(), prop.jcts.len());
        let a = tune.jct_stats().avg_s;
        let b = prop.jct_stats().avg_s;
        assert!(
            a < b,
            "het-tune avg JCT {a} must beat type-blind {b}"
        );
    }

    #[test]
    fn profiling_cost_scales_with_types() {
        let jobs = trace(10, 3);
        let het = run("het-tune", jobs.clone());
        // Homogeneous equivalent for the same jobs profiles one type.
        let hom = crate::sim::Simulator::new(crate::sim::SimConfig {
            n_servers: 16,
            policy: "fifo".into(),
            mechanism: "tune".into(),
            ..Default::default()
        })
        .run(jobs);
        assert!(
            het.profiling_minutes > hom.profiling_minutes,
            "het profiling {} must exceed homogeneous {}",
            het.profiling_minutes,
            hom.profiling_minutes
        );
    }

    #[test]
    fn quotas_cap_flooding_tenant_on_hetero_cluster() {
        use crate::job::{ModelKind, TenantId};
        use crate::metrics::jains_index;
        // 1×P100 + 2×V100 machines = 24 GPUs. Tenant 0 floods the queue
        // with 24 identical one-GPU jobs (exactly the cluster capacity);
        // tenant 1 queues 24 more behind them. FIFO alone hands round 0
        // entirely to tenant 0; a 1:1 quota must cap each tenant at 12
        // GPUs per round, so half of tenant 1's backlog starts immediately
        // instead of waiting out tenant 0's. Identical durations make the
        // comparison deterministic (no heavy-tail sampling luck).
        let mk_jobs = || -> Vec<Job> {
            (0..48u64)
                .map(|i| {
                    Job::new(JobId(i), ModelKind::Lstm, 1, 0.0, 3600.0)
                        .with_tenant(TenantId(if i < 24 { 0 } else { 1 }))
                })
                .collect()
        };
        let cfg = || HeteroSimConfig {
            types: vec![
                TypeSpec {
                    gen: GpuGen::P100,
                    spec: ServerSpec::default(),
                    machines: 1,
                },
                TypeSpec {
                    gen: GpuGen::V100,
                    spec: ServerSpec::default(),
                    machines: 2,
                },
            ],
            policy: "fifo".into(),
            mechanism: "het-tune".into(),
            ..Default::default()
        };
        let quotas = TenantQuotas::new()
            .with(TenantId(0), 1.0)
            .with(TenantId(1), 1.0);
        let plain = HeteroSimulator::new(cfg()).run(mk_jobs());
        let fair =
            HeteroSimulator::with_quotas(cfg(), Some(quotas)).run(mk_jobs());
        assert_eq!(plain.jcts.len(), 48);
        assert_eq!(fair.jcts.len(), 48);
        let p = plain.tenant_stats();
        let f = fair.tenant_stats();
        let (p0, p1) = (p[&TenantId(0)].avg_s, p[&TenantId(1)].avg_s);
        let (f0, f1) = (f[&TenantId(0)].avg_s, f[&TenantId(1)].avg_s);
        // Without quotas FIFO starves tenant 1 behind tenant 0's backlog.
        assert!(
            p1 > p0 * 1.2,
            "fifo baseline should favour the flooding tenant: {p0} vs {p1}"
        );
        // Quotas must strictly help the starved tenant (half its jobs now
        // start in round 0 instead of waiting out tenant 0's backlog)...
        assert!(
            f1 < p1 - 1.0,
            "quotas must speed up the starved tenant: {f1} vs {p1}"
        );
        // ...and improve Jain fairness over per-tenant average JCTs.
        assert!(
            jains_index(&[f0, f1]) > jains_index(&[p0, p1]),
            "quotas must improve fairness: fair ({f0}, {f1}) vs plain \
             ({p0}, {p1})"
        );
    }
}
