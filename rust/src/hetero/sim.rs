//! Round-based trace simulator over a heterogeneous cluster.
//!
//! Mirrors the homogeneous engine ([`crate::sim`]): arrivals are
//! profiled (on every machine type, A.2), a scheduling policy orders the
//! queue, the runnable set is admitted against cluster-wide free GPUs,
//! and a [`HetMechanism`] assigns each job a type + allocation. Progress
//! accrues at the *granted* throughput on the *assigned type* — so a job
//! bounced between generations across rounds advances at whatever each
//! round's hardware actually delivers.
//!
//! Work accounting: a job's `total_samples` is derived from its trace
//! duration under the fairness oracle's throughput (`W_j^Fair`,
//! slowest-type proportional), making "duration" hardware-meaningful in
//! the heterogeneous setting too.

use super::cluster::HeteroCluster;
use super::mechanism::{het_by_name, HetJobRequest, HetMechanism};
use super::perf::HeteroPerfModel;
use super::profiler::{HeteroProfiler, HeteroSensitivity};
use crate::cluster::ServerSpec;
use crate::hetero::TypeSpec;
use crate::job::{Job, JobId, JobState};
use crate::metrics::JctStats;
use crate::policy::{by_name as policy_by_name, PolicyJobView};
use std::collections::BTreeMap;

/// Heterogeneous simulator configuration.
pub struct HeteroSimConfig {
    pub types: Vec<TypeSpec>,
    pub round_s: f64,
    pub policy: String,
    pub mechanism: String,
    pub profile_noise: f64,
    pub max_sim_s: f64,
}

impl Default for HeteroSimConfig {
    fn default() -> Self {
        let spec = ServerSpec::default();
        HeteroSimConfig {
            types: vec![
                TypeSpec {
                    gen: super::GpuGen::P100,
                    spec,
                    machines: 8,
                },
                TypeSpec {
                    gen: super::GpuGen::V100,
                    spec,
                    machines: 8,
                },
            ],
            round_s: 300.0,
            policy: "srtf".into(),
            mechanism: "het-tune".into(),
            profile_noise: 0.0,
            max_sim_s: 400.0 * 24.0 * 3600.0,
        }
    }
}

/// Simulation output.
#[derive(Debug)]
pub struct HeteroSimResult {
    /// (job id, jct seconds, profiled cost minutes).
    pub jcts: Vec<(JobId, f64)>,
    pub makespan_s: f64,
    pub rounds: usize,
    pub profiling_minutes: f64,
}

impl HeteroSimResult {
    pub fn jct_stats(&self) -> JctStats {
        let v: Vec<f64> = self.jcts.iter().map(|&(_, j)| j).collect();
        JctStats::from_jcts(&v)
    }
}

/// The heterogeneous simulator.
pub struct HeteroSimulator {
    cfg: HeteroSimConfig,
}

impl HeteroSimulator {
    pub fn new(cfg: HeteroSimConfig) -> HeteroSimulator {
        HeteroSimulator { cfg }
    }

    /// Run a trace to completion (or `max_sim_s`).
    pub fn run(&self, mut jobs: Vec<Job>) -> HeteroSimResult {
        let mut cluster = HeteroCluster::new(&self.cfg.types);
        let worlds: BTreeMap<_, _> = cluster
            .groups
            .iter()
            .map(|g| {
                (g.gen, HeteroPerfModel::new(g.cluster.spec, g.gen))
            })
            .collect();
        let profiler = {
            let mut p = HeteroProfiler::for_cluster(&cluster);
            p.noise_sd = self.cfg.profile_noise;
            p
        };
        let policy = policy_by_name(&self.cfg.policy)
            .unwrap_or_else(|| panic!("unknown policy {}", self.cfg.policy));
        let mechanism: Box<dyn HetMechanism> =
            het_by_name(&self.cfg.mechanism).unwrap_or_else(|| {
                panic!("unknown het mechanism {}", self.cfg.mechanism)
            });

        jobs.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        let max_group_gpus = cluster
            .groups
            .iter()
            .map(|g| g.cluster.total_gpus())
            .max()
            .unwrap_or(0);
        // A job must fit inside one type group (A.2.2: no cross-type
        // spans).
        jobs.retain(|j| j.gpus <= max_group_gpus);
        let n_total = jobs.len();

        let mut sens: BTreeMap<JobId, HeteroSensitivity> = BTreeMap::new();
        let mut active: BTreeMap<JobId, Job> = BTreeMap::new();
        let mut jcts: Vec<(JobId, f64)> = Vec::new();
        let mut profiling_minutes = 0.0;
        let mut next_arrival = 0usize;
        let mut now = 0.0f64;
        let mut rounds = 0usize;

        while jcts.len() < n_total && now < self.cfg.max_sim_s {
            // Admit + profile arrivals.
            while next_arrival < jobs.len()
                && jobs[next_arrival].arrival_s <= now + 1e-9
            {
                let mut job = jobs[next_arrival].clone();
                let s = profiler.profile(&job);
                profiling_minutes += s.cost_minutes;
                job.total_samples =
                    job.duration_prop_s * s.fair_throughput();
                sens.insert(job.id, s);
                active.insert(job.id, job);
                next_arrival += 1;
            }

            // Policy order over the active set.
            let total_gpus = cluster.total_gpus();
            let total_cpus = cluster.total_cpus();
            let total_mem = cluster.total_mem_gb();
            let mut views: Vec<PolicyJobView> = active
                .values()
                .map(|j| {
                    let s = &sens[&j.id];
                    let fair = s.fair_throughput();
                    let remaining_est_s = if fair > 0.0 {
                        j.remaining_samples() / fair
                    } else {
                        f64::INFINITY
                    };
                    PolicyJobView {
                        id: j.id,
                        arrival_s: j.arrival_s,
                        attained_service_s: j.attained_service_s,
                        remaining_est_s,
                        duration_prop_s: j.duration_prop_s,
                        gpus: j.gpus,
                        dominant_share: j.gpus as f64 / total_gpus as f64,
                        alignment: (j.gpus as f64 * total_gpus as f64)
                            / (total_cpus * total_mem).max(1.0),
                    }
                })
                .collect();
            policy.order(&mut views, now);

            // Admission: aggregate GPU demand fits the free pool.
            let mut admitted_gpus = 0u32;
            let mut runnable: Vec<JobId> = Vec::new();
            for v in &views {
                let gpus = active[&v.id].gpus;
                if admitted_gpus + gpus <= total_gpus {
                    admitted_gpus += gpus;
                    runnable.push(v.id);
                }
            }

            // Allocate.
            cluster.evict_all();
            let requests: Vec<HetJobRequest<'_>> = runnable
                .iter()
                .map(|id| HetJobRequest {
                    id: *id,
                    gpus: active[id].gpus,
                    sens: &sens[id],
                })
                .collect();
            let grants = mechanism.allocate(&mut cluster, &requests);
            debug_assert!(cluster.check_consistency().is_ok());

            // Deploy: progress rates from the assigned type's ground
            // truth at the granted allocation.
            for job in active.values_mut() {
                match grants.get(&job.id) {
                    Some(g) => {
                        job.state = JobState::Running;
                        job.progress_rate = worlds[&g.gen].throughput(
                            job.model,
                            job.gpus,
                            g.grant.demand.cpus,
                            g.grant.demand.mem_gb,
                        );
                    }
                    None => {
                        job.state = JobState::Queued;
                        job.progress_rate = 0.0;
                    }
                }
            }

            // Advance to the earlier of round end / next arrival.
            let round_end = now + self.cfg.round_s;
            let horizon = if next_arrival < jobs.len() {
                round_end.min(jobs[next_arrival].arrival_s.max(now + 1e-6))
            } else {
                round_end
            };
            let dt = horizon - now;
            let mut done: Vec<JobId> = Vec::new();
            for job in active.values_mut() {
                if job.state != JobState::Running || job.progress_rate <= 0.0
                {
                    continue;
                }
                let need = job.remaining_samples() / job.progress_rate;
                if need <= dt {
                    job.finish_s = now + need;
                    job.attained_service_s += need;
                    job.progress_samples = job.total_samples;
                    done.push(job.id);
                } else {
                    job.progress_samples += job.progress_rate * dt;
                    job.attained_service_s += dt;
                }
            }
            for id in done {
                let j = active.remove(&id).unwrap();
                sens.remove(&id);
                jcts.push((id, j.finish_s - j.arrival_s));
            }

            rounds += 1;
            if active.is_empty() && next_arrival < jobs.len() {
                now = jobs[next_arrival].arrival_s;
            } else {
                now = horizon;
            }
        }

        let makespan_s = now;
        HeteroSimResult { jcts, makespan_s, rounds, profiling_minutes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{generate, Split, TraceConfig};

    fn trace(n: usize, seed: u64) -> Vec<Job> {
        generate(&TraceConfig {
            n_jobs: n,
            split: Split::new(40, 40, 20),
            multi_gpu: false,
            jobs_per_hour: None,
            seed,
        })
    }

    fn run(mechanism: &str, jobs: Vec<Job>) -> HeteroSimResult {
        let sim = HeteroSimulator::new(HeteroSimConfig {
            mechanism: mechanism.into(),
            policy: "fifo".into(),
            ..Default::default()
        });
        sim.run(jobs)
    }

    #[test]
    fn all_jobs_finish() {
        let r = run("het-tune", trace(40, 7));
        assert_eq!(r.jcts.len(), 40);
        assert!(r.rounds > 0);
        assert!(r.jcts.iter().all(|&(_, j)| j > 0.0 && j.is_finite()));
    }

    #[test]
    fn het_tune_beats_type_blind_proportional() {
        let jobs = trace(60, 21);
        let tune = run("het-tune", jobs.clone());
        let prop = run("het-proportional", jobs);
        assert_eq!(tune.jcts.len(), prop.jcts.len());
        let a = tune.jct_stats().avg_s;
        let b = prop.jct_stats().avg_s;
        assert!(
            a < b,
            "het-tune avg JCT {a} must beat type-blind {b}"
        );
    }

    #[test]
    fn profiling_cost_scales_with_types() {
        let jobs = trace(10, 3);
        let het = run("het-tune", jobs.clone());
        // Homogeneous equivalent for the same jobs profiles one type.
        let hom = crate::sim::Simulator::new(crate::sim::SimConfig {
            n_servers: 16,
            policy: "fifo".into(),
            mechanism: "tune".into(),
            ..Default::default()
        })
        .run(jobs);
        assert!(
            het.profiling_minutes > hom.profiling_minutes,
            "het profiling {} must exceed homogeneous {}",
            het.profiling_minutes,
            hom.profiling_minutes
        );
    }
}
