//! Heterogeneous front-end (paper Appendix A.2) — a *configuration* of
//! the one type-generic stack, not a second implementation.
//!
//! Since the one-resource-model unification, everything this module used
//! to implement lives in the canonical layers, with heterogeneity as
//! data rather than a code fork:
//!
//! - machine types: [`crate::cluster::GpuGen`] on every server, pooled
//!   by [`crate::cluster::Fleet`] (was `hetero::{gen, cluster}`);
//! - ground truth: [`crate::perf::PerfModel::with_gen`] (was
//!   `HeteroPerfModel`);
//! - profiling: [`crate::profiler::OptimisticProfiler::for_fleet`]
//!   produces the 3-D `W_j[k][c, m]` [`crate::profiler::Sensitivity`]
//!   (was `HeteroProfiler`/`HeteroSensitivity`);
//! - mechanisms: [`crate::mechanism`]'s `Proportional`/`Tune`/`Opt` do
//!   A.2.2 type assignment natively, a no-op pass-through on one type
//!   (was `HetProportional`/`HetTune`/`HetOpt` + `HetMechanism`);
//! - simulation: [`crate::sim::FleetModel`] behind the shared event core
//!   (was `HeteroModel`).
//!
//! What remains here is the heterogeneous *front-end*: a config type
//! whose default is the two-generation evaluation fleet, a simulator
//! wrapper that forwards to [`Simulator`] with
//! [`crate::sim::SimConfig::types`] set, and name re-exports for
//! pre-unification callers. A single-type V100 `HeteroSimConfig`
//! reproduces the homogeneous schedule bit-for-bit
//! (`tests/scenarios.rs`).

pub use crate::cluster::{Fleet as HeteroCluster, GpuGen, TypePool, TypeSpec};
pub use crate::mechanism::{Grant as HetGrant, JobRequest as HetJobRequest};
pub use crate::profiler::Sensitivity as HeteroSensitivity;
pub use crate::sim::FleetModel as HeteroModel;

use crate::cluster::{ServerSpec, TopologySpec};
use crate::job::{Job, JobId, TenantId};
use crate::metrics::{per_tenant_stats, JctStats, UtilizationLog};
use crate::sim::{FaultSpec, FinishedJob, SimConfig, SimResult, Simulator};
use crate::workload::TenantQuotas;
use std::collections::BTreeMap;

/// Heterogeneous simulator configuration: the fleet description plus the
/// shared engine knobs.
pub struct HeteroSimConfig {
    pub types: Vec<TypeSpec>,
    pub round_s: f64,
    pub policy: String,
    pub mechanism: String,
    pub profile_noise: f64,
    pub max_sim_s: f64,
    /// Rack topology, concretized per pool (`--topology racks:R`); the
    /// default flat spec is the pre-topology behaviour.
    pub topology: TopologySpec,
    /// Deterministic host-churn schedule (`--faults ...`); `None` (the
    /// default) is byte-identical to pre-fault builds.
    pub faults: Option<FaultSpec>,
}

impl Default for HeteroSimConfig {
    fn default() -> Self {
        let spec = ServerSpec::default();
        HeteroSimConfig {
            types: vec![
                TypeSpec { gen: GpuGen::P100, spec, machines: 8 },
                TypeSpec { gen: GpuGen::V100, spec, machines: 8 },
            ],
            round_s: 300.0,
            policy: "srtf".into(),
            mechanism: "het-tune".into(),
            profile_noise: 0.0,
            max_sim_s: 400.0 * 24.0 * 3600.0,
            topology: TopologySpec::default(),
            faults: None,
        }
    }
}

/// Simulation output (the pre-unification shape, derived from the shared
/// core's [`SimResult`]).
#[derive(Debug)]
pub struct HeteroSimResult {
    /// (job id, jct seconds) in completion order.
    pub jcts: Vec<(JobId, f64)>,
    pub makespan_s: f64,
    pub rounds: usize,
    /// Rounds that actually ran the allocation mechanism (the rest were
    /// memoized/fast-forwarded; shared-core accounting).
    pub planned_rounds: usize,
    /// Planned rounds that resumed from the previous plan's checkpoint
    /// (prefix-resume tier; shared-core accounting).
    pub resumed_rounds: usize,
    /// Total per-job planning steps across all planned rounds
    /// (shared-core accounting).
    pub plan_steps_total: usize,
    /// Of `plan_steps_total`, the steps served from checkpointed
    /// prefixes.
    pub plan_steps_reused: usize,
    pub profiling_minutes: f64,
    /// Gang placements preempted back into the queue by host failures
    /// (shared-core fault accounting; 0 without `--faults`).
    pub preemptions: u64,
    /// GPU-rounds of partial work lost to preemption.
    pub preempted_gpu_rounds_lost: u64,
    /// `ServerFailed` events applied.
    pub servers_failed: u64,
    /// `ServerAdded` events applied (restore or grow).
    pub servers_restored: u64,
    /// Full per-job records (tenant-tagged), from the shared core.
    pub finished: Vec<FinishedJob>,
    /// Per-round utilization samples (shared-core accounting).
    pub utilization: UtilizationLog,
}

impl HeteroSimResult {
    fn from_result(r: SimResult) -> HeteroSimResult {
        HeteroSimResult {
            jcts: r.finished.iter().map(|f| (f.id, f.jct_s)).collect(),
            makespan_s: r.makespan_s,
            rounds: r.rounds,
            planned_rounds: r.planned_rounds,
            resumed_rounds: r.resumed_rounds,
            plan_steps_total: r.plan_steps_total,
            plan_steps_reused: r.plan_steps_reused,
            profiling_minutes: r.profiling_minutes,
            preemptions: r.preemptions,
            preempted_gpu_rounds_lost: r.preempted_gpu_rounds_lost,
            servers_failed: r.servers_failed,
            servers_restored: r.servers_restored,
            finished: r.finished,
            utilization: r.utilization,
        }
    }

    pub fn jct_stats(&self) -> JctStats {
        let v: Vec<f64> = self.jcts.iter().map(|&(_, j)| j).collect();
        JctStats::from_jcts(&v)
    }

    /// Per-tenant JCT summaries (multi-tenant workloads).
    pub fn tenant_stats(&self) -> BTreeMap<TenantId, JctStats> {
        let pairs: Vec<(TenantId, f64)> =
            self.finished.iter().map(|f| (f.tenant, f.jct_s)).collect();
        per_tenant_stats(&pairs)
    }

    /// Round-planning summary — same accounting as
    /// [`SimResult::plan_summary`].
    pub fn plan_summary(&self) -> crate::metrics::PlanSummary {
        crate::metrics::PlanSummary {
            planned_rounds: self.planned_rounds,
            resumed_rounds: self.resumed_rounds,
            reused_steps: self.plan_steps_reused,
            total_steps: self.plan_steps_total,
        }
    }

    /// Churn/preemption summary — same accounting as
    /// [`SimResult::fault_summary`].
    pub fn fault_summary(&self) -> crate::metrics::FaultSummary {
        crate::metrics::FaultSummary {
            preemptions: self.preemptions,
            preempted_gpu_rounds_lost: self.preempted_gpu_rounds_lost,
            servers_failed: self.servers_failed,
            servers_restored: self.servers_restored,
        }
    }

    /// The canonical metrics document — byte-compatible with
    /// [`SimResult::metrics_json`], so `synergy hetero --json` and
    /// `synergy sim --json` emit the same payload shape. `plan_stats`
    /// (default off) appends the round-planning split; `fault_stats`
    /// (default off) appends the churn/preemption counters.
    pub fn metrics_json(&self, plan_stats: bool, fault_stats: bool) -> String {
        let summary = self.plan_summary();
        let faults = self.fault_summary();
        crate::metrics::metrics_json(
            &self.jct_stats(),
            &self.tenant_stats(),
            self.makespan_s,
            self.rounds,
            plan_stats.then_some(&summary),
            fault_stats.then_some(&faults),
        )
    }
}

/// The heterogeneous simulator: [`Simulator`] with the fleet description
/// set. One engine, two front-ends.
pub struct HeteroSimulator {
    cfg: HeteroSimConfig,
    quotas: Option<TenantQuotas>,
}

impl HeteroSimulator {
    pub fn new(cfg: HeteroSimConfig) -> HeteroSimulator {
        HeteroSimulator { cfg, quotas: None }
    }

    /// A heterogeneous simulator whose admission enforces tenant GPU
    /// quotas (the same weighted-quota + work-conserving-spill admission
    /// as the homogeneous front-end, via the shared core).
    pub fn with_quotas(
        cfg: HeteroSimConfig,
        quotas: Option<TenantQuotas>,
    ) -> HeteroSimulator {
        let mut sim = HeteroSimulator::new(cfg);
        sim.quotas = quotas;
        sim
    }

    /// Run a trace to completion (or `max_sim_s`) through the shared
    /// event-driven core.
    pub fn run(&self, jobs: Vec<Job>) -> HeteroSimResult {
        let sim = Simulator::with_quotas(
            SimConfig {
                types: Some(self.cfg.types.clone()),
                round_s: self.cfg.round_s,
                policy: self.cfg.policy.clone(),
                mechanism: self.cfg.mechanism.clone(),
                profile_noise: self.cfg.profile_noise,
                max_sim_s: self.cfg.max_sim_s,
                topology: self.cfg.topology,
                faults: self.cfg.faults.clone(),
                ..SimConfig::default()
            },
            self.quotas.clone(),
        );
        HeteroSimResult::from_result(sim.run(jobs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{generate, Split, TraceConfig};

    fn trace(n: usize, seed: u64) -> Vec<Job> {
        generate(&TraceConfig {
            n_jobs: n,
            split: Split::new(40, 40, 20),
            multi_gpu: false,
            jobs_per_hour: None,
            seed,
        })
    }

    fn run(mechanism: &str, jobs: Vec<Job>) -> HeteroSimResult {
        let sim = HeteroSimulator::new(HeteroSimConfig {
            mechanism: mechanism.into(),
            policy: "fifo".into(),
            ..Default::default()
        });
        sim.run(jobs)
    }

    #[test]
    fn all_jobs_finish() {
        let r = run("het-tune", trace(40, 7));
        assert_eq!(r.jcts.len(), 40);
        assert!(r.rounds > 0);
        assert!(r.jcts.iter().all(|&(_, j)| j > 0.0 && j.is_finite()));
    }

    #[test]
    fn het_tune_beats_type_blind_proportional() {
        let jobs = trace(60, 21);
        let tune = run("het-tune", jobs.clone());
        let prop = run("het-proportional", jobs);
        assert_eq!(tune.jcts.len(), prop.jcts.len());
        let a = tune.jct_stats().avg_s;
        let b = prop.jct_stats().avg_s;
        assert!(
            a < b,
            "het-tune avg JCT {a} must beat type-blind {b}"
        );
    }

    #[test]
    fn profiling_cost_scales_with_types() {
        let jobs = trace(10, 3);
        let het = run("het-tune", jobs.clone());
        // Homogeneous equivalent for the same jobs profiles one type.
        let hom = Simulator::new(SimConfig {
            n_servers: 16,
            policy: "fifo".into(),
            mechanism: "tune".into(),
            ..Default::default()
        })
        .run(jobs);
        assert!(
            het.profiling_minutes > hom.profiling_minutes,
            "het profiling {} must exceed homogeneous {}",
            het.profiling_minutes,
            hom.profiling_minutes
        );
    }

    #[test]
    fn quotas_cap_flooding_tenant_on_hetero_cluster() {
        use crate::job::ModelKind;
        use crate::metrics::jains_index;
        // 1×P100 + 2×V100 machines = 24 GPUs. Tenant 0 floods the queue
        // with 24 identical one-GPU jobs (exactly the cluster capacity);
        // tenant 1 queues 24 more behind them. FIFO alone hands round 0
        // entirely to tenant 0; a 1:1 quota must cap each tenant at 12
        // GPUs per round, so half of tenant 1's backlog starts immediately
        // instead of waiting out tenant 0's. Identical durations make the
        // comparison deterministic (no heavy-tail sampling luck).
        let mk_jobs = || -> Vec<Job> {
            (0..48u64)
                .map(|i| {
                    Job::new(JobId(i), ModelKind::Lstm, 1, 0.0, 3600.0)
                        .with_tenant(TenantId(if i < 24 { 0 } else { 1 }))
                })
                .collect()
        };
        let cfg = || HeteroSimConfig {
            types: vec![
                TypeSpec {
                    gen: GpuGen::P100,
                    spec: ServerSpec::default(),
                    machines: 1,
                },
                TypeSpec {
                    gen: GpuGen::V100,
                    spec: ServerSpec::default(),
                    machines: 2,
                },
            ],
            policy: "fifo".into(),
            mechanism: "het-tune".into(),
            ..Default::default()
        };
        let quotas = TenantQuotas::new()
            .with(TenantId(0), 1.0)
            .with(TenantId(1), 1.0);
        let plain = HeteroSimulator::new(cfg()).run(mk_jobs());
        let fair =
            HeteroSimulator::with_quotas(cfg(), Some(quotas)).run(mk_jobs());
        assert_eq!(plain.jcts.len(), 48);
        assert_eq!(fair.jcts.len(), 48);
        let p = plain.tenant_stats();
        let f = fair.tenant_stats();
        let (p0, p1) = (p[&TenantId(0)].avg_s, p[&TenantId(1)].avg_s);
        let (f0, f1) = (f[&TenantId(0)].avg_s, f[&TenantId(1)].avg_s);
        // Without quotas FIFO starves tenant 1 behind tenant 0's backlog.
        assert!(
            p1 > p0 * 1.2,
            "fifo baseline should favour the flooding tenant: {p0} vs {p1}"
        );
        // Quotas must strictly help the starved tenant (half its jobs now
        // start in round 0 instead of waiting out tenant 0's backlog)...
        assert!(
            f1 < p1 - 1.0,
            "quotas must speed up the starved tenant: {f1} vs {p1}"
        );
        // ...and improve Jain fairness over per-tenant average JCTs.
        assert!(
            jains_index(&[f0, f1]) > jains_index(&[p0, p1]),
            "quotas must improve fairness: fair ({f0}, {f1}) vs plain \
             ({p0}, {p1})"
        );
    }
}
