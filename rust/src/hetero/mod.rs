//! Heterogeneous GPU clusters (paper Appendix A.2).
//!
//! The paper's main system targets homogeneous clusters (§2.3) but the
//! appendix extends the formulation to clusters with several *types*
//! (generations) of GPU machines: the sensitivity matrix gains a type
//! dimension (`W_ij[c, m]` — progress of job `j` on machine type `i`),
//! the LP selects one `(c, m, i)` configuration per job, and a job is
//! never split across two types in a round (A.2.2).
//!
//! This module implements that extension end-to-end:
//!
//! - [`GpuGen`] — GPU generations with per-task compute scaling
//!   ([`gen`]);
//! - [`HeteroCluster`] — a set of homogeneous type-groups, each reusing
//!   the [`crate::cluster::Cluster`] bookkeeping ([`cluster`]);
//! - [`HeteroPerfModel`] — ground truth: the homogeneous pipeline model
//!   with the GPU stage scaled by generation ([`perf`]);
//! - [`HeteroProfiler`] — optimistic profiling along the extra type
//!   dimension, producing one [`crate::profiler::SensitivityMatrix`] per
//!   type at `|K|×` the profiling cost (A.2: "at an additional profiling
//!   cost") ([`profiler`]);
//! - [`HetTune`] / [`HetOpt`] / [`HetProportional`] — the scheduling
//!   mechanisms: a TUNE-style heuristic that assigns each job a type and
//!   reuses homogeneous Synergy-TUNE within the type group; the A.2.3
//!   ILP upper bound; and a type-blind GPU-proportional baseline
//!   ([`mechanism`]);
//! - [`HeteroSimulator`] — a round-based trace simulator over the
//!   heterogeneous cluster ([`sim`]).
//!
//! **Fairness oracle.** A.2.2 assumes the per-job fair throughput
//! `W_j^Fair` is supplied by an oracle (a heterogeneity-aware fair
//! scheduler such as Gavel [44]). We implement the conservative oracle:
//! the GPU-proportional throughput on the *slowest* generation present.
//! Because throughput is monotone in the GPU stage rate at fixed (c, m),
//! a proportional allocation on any type dominates this floor, so every
//! mechanism here satisfies the constraint structurally (tested in
//! [`mechanism`]).

pub mod cluster;
pub mod gen;
pub mod mechanism;
pub mod perf;
pub mod profiler;
pub mod sim;

pub use cluster::{HeteroCluster, TypeGroup, TypeSpec};
pub use gen::GpuGen;
pub use mechanism::{
    het_by_name, HetGrant, HetJobRequest, HetMechanism, HetOpt,
    HetOptAllocation, HetProportional, HetTune, ALL_HET_MECHANISMS,
};
pub use perf::HeteroPerfModel;
pub use profiler::{HeteroProfiler, HeteroSensitivity};
pub use sim::{HeteroModel, HeteroSimConfig, HeteroSimResult, HeteroSimulator};
