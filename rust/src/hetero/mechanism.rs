//! Heterogeneous scheduling mechanisms (paper A.2.2–A.2.3).
//!
//! Three mechanisms over a [`HeteroCluster`]:
//!
//! - [`HetProportional`] — the type-blind baseline: jobs take types in
//!   capacity-weighted round-robin order and receive GPU-proportional
//!   CPU/memory, mirroring what a heterogeneity-unaware cluster does.
//! - [`HetTune`] — the TUNE-style heuristic: each job is first assigned
//!   the machine type that maximizes its best-case throughput among
//!   types with free GPUs (jobs never span types in a round, A.2.2),
//!   then homogeneous Synergy-TUNE runs inside each type group with the
//!   job's per-type sensitivity matrix. The fairness floor `W_j^Fair`
//!   (slowest-type proportional, see [`super::profiler`]) holds
//!   structurally: TUNE guarantees at least the assigned type's
//!   proportional throughput, which dominates the slowest type's.
//! - [`HetOpt`] — the A.2.3 ILP upper bound: boolean `y_{c,m,i,j}` picks
//!   one (CPU, memory, type) configuration per job, maximizing aggregate
//!   throughput subject to per-type GPU/CPU/memory capacity (23–24), one
//!   configuration per job (25), and the oracle fairness floor (26).

use super::cluster::HeteroCluster;
use super::gen::GpuGen;
use super::profiler::HeteroSensitivity;
use crate::job::{DemandVector, JobId};
use crate::lp::{solve_ilp, IlpOptions, Lp, Op};
use crate::mechanism::{Grant, JobRequest, Mechanism, Proportional, Tune};
use std::collections::BTreeMap;

/// One runnable job as the heterogeneous mechanisms see it.
#[derive(Debug, Clone)]
pub struct HetJobRequest<'a> {
    pub id: JobId,
    pub gpus: u32,
    pub sens: &'a HeteroSensitivity,
}

/// The outcome for one job: the machine type plus the in-group grant.
#[derive(Debug, Clone)]
pub struct HetGrant {
    pub gen: GpuGen,
    pub grant: Grant,
}

/// Heterogeneous allocation mechanism interface.
pub trait HetMechanism: Send + Sync {
    fn name(&self) -> &'static str;

    /// Place as many of `jobs` (policy priority order) as the cluster
    /// allows. The cluster must start the round with no placements.
    fn allocate(
        &self,
        cluster: &mut HeteroCluster,
        jobs: &[HetJobRequest<'_>],
    ) -> BTreeMap<JobId, HetGrant>;
}

// ---------------------------------------------------------------------------
// Type assignment + per-group delegation
// ---------------------------------------------------------------------------

/// Assign each job a machine type in priority order. `score` ranks the
/// candidate types for one job (higher wins); only types whose remaining
/// free GPU budget covers the job are candidates.
fn assign_types(
    cluster: &HeteroCluster,
    jobs: &[HetJobRequest<'_>],
    score: impl Fn(&HetJobRequest<'_>, GpuGen) -> f64,
) -> BTreeMap<JobId, GpuGen> {
    let mut free: BTreeMap<GpuGen, u32> = cluster
        .groups
        .iter()
        .map(|g| (g.gen, g.cluster.free_gpus()))
        .collect();
    let mut assigned = BTreeMap::new();
    for j in jobs {
        let best = free
            .iter()
            .filter(|(_, &f)| f >= j.gpus)
            .map(|(&g, _)| g)
            .max_by(|&a, &b| {
                score(j, a)
                    .partial_cmp(&score(j, b))
                    .unwrap()
                    .then(a.cmp(&b))
            });
        if let Some(gen) = best {
            *free.get_mut(&gen).unwrap() -= j.gpus;
            assigned.insert(j.id, gen);
        }
        // Jobs with no feasible type this round stay queued (GPU
        // shortage — same as the homogeneous runnable-set cut).
    }
    assigned
}

/// Run a homogeneous mechanism inside each type group over the jobs
/// assigned to it.
fn delegate_groups(
    cluster: &mut HeteroCluster,
    jobs: &[HetJobRequest<'_>],
    assigned: &BTreeMap<JobId, GpuGen>,
    inner: &dyn Mechanism,
) -> BTreeMap<JobId, HetGrant> {
    let mut out = BTreeMap::new();
    for group in &mut cluster.groups {
        let spec = group.cluster.spec;
        let requests: Vec<JobRequest<'_>> = jobs
            .iter()
            .filter(|j| assigned.get(&j.id) == Some(&group.gen))
            .map(|j| {
                let matrix = j
                    .sens
                    .matrix(group.gen)
                    .expect("job profiled on every type");
                JobRequest {
                    id: j.id,
                    gpus: j.gpus,
                    best: matrix.best_demand(),
                    prop: DemandVector::proportional(
                        j.gpus,
                        spec.cpus as f64 / spec.gpus as f64,
                        spec.mem_gb / spec.gpus as f64,
                    ),
                    matrix,
                }
            })
            .collect();
        for (id, grant) in inner.allocate(&mut group.cluster, &requests) {
            out.insert(id, HetGrant { gen: group.gen, grant });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Mechanisms
// ---------------------------------------------------------------------------

/// Type-blind GPU-proportional baseline.
pub struct HetProportional;

impl HetMechanism for HetProportional {
    fn name(&self) -> &'static str {
        "het-proportional"
    }

    fn allocate(
        &self,
        cluster: &mut HeteroCluster,
        jobs: &[HetJobRequest<'_>],
    ) -> BTreeMap<JobId, HetGrant> {
        // Type-blind: prefer whichever type has the most free GPUs
        // (capacity-weighted round-robin), ignoring job sensitivity.
        let mut free: BTreeMap<GpuGen, u32> = cluster
            .groups
            .iter()
            .map(|g| (g.gen, g.cluster.free_gpus()))
            .collect();
        let mut assigned = BTreeMap::new();
        for j in jobs {
            let best = free
                .iter()
                .filter(|(_, &f)| f >= j.gpus)
                .max_by_key(|(&g, &f)| (f, std::cmp::Reverse(g)))
                .map(|(&g, _)| g);
            if let Some(gen) = best {
                *free.get_mut(&gen).unwrap() -= j.gpus;
                assigned.insert(j.id, gen);
            }
        }
        delegate_groups(cluster, jobs, &assigned, &Proportional)
    }
}

/// Heterogeneity-aware Synergy-TUNE.
pub struct HetTune;

impl HetMechanism for HetTune {
    fn name(&self) -> &'static str {
        "het-tune"
    }

    fn allocate(
        &self,
        cluster: &mut HeteroCluster,
        jobs: &[HetJobRequest<'_>],
    ) -> BTreeMap<JobId, HetGrant> {
        // Affinity score: the job's best-case throughput on this type,
        // normalized by the type's compute scale so compute-insensitive
        // jobs defer fast GPUs to jobs that can exploit them.
        let assigned = assign_types(cluster, jobs, |j, gen| {
            let m = j.sens.matrix(gen).expect("profiled");
            let peak = m.max_throughput();
            let scale = gen.compute_scale(m.model.task());
            peak / scale
        });
        delegate_groups(cluster, jobs, &assigned, &Tune::default())
    }
}

/// The A.2.3 ILP solution for one round.
#[derive(Debug, Clone)]
pub struct HetOptAllocation {
    /// Chosen (type, cpus, mem_gb, throughput) per job.
    pub chosen: BTreeMap<JobId, (GpuGen, f64, f64, f64)>,
    /// ILP objective — aggregate throughput upper bound.
    pub objective: f64,
    pub n_vars: usize,
}

/// Heterogeneous Synergy-OPT (ILP upper bound).
#[derive(Default)]
pub struct HetOpt;

impl HetOpt {
    /// Solve the A.2.3 ILP. Options per (job, type) are Pareto-pruned and
    /// floored against the oracle `W_j^Fair` (constraint 26), so every
    /// selection is fair by construction.
    pub fn solve_allocation(
        &self,
        cluster: &HeteroCluster,
        jobs: &[HetJobRequest<'_>],
    ) -> Option<HetOptAllocation> {
        if jobs.is_empty() {
            return Some(HetOptAllocation {
                chosen: BTreeMap::new(),
                objective: 0.0,
                n_vars: 0,
            });
        }
        // (job, gen, options) — options only on types that could ever
        // host the job's gang (GPU capacity of the whole group).
        struct Block {
            id: JobId,
            gpus: u32,
            gen: GpuGen,
            opts: Vec<(f64, f64, f64)>,
        }
        let mut blocks: Vec<Block> = Vec::new();
        for j in jobs {
            let fair = j.sens.fair_throughput();
            for group in &cluster.groups {
                if group.cluster.total_gpus() < j.gpus {
                    continue;
                }
                let m = j.sens.matrix(group.gen).expect("profiled");
                let mut opts = m.pareto_options_with_floor(fair);
                if opts.is_empty() && m.proportional_throughput() >= fair {
                    opts.push(m.proportional_option());
                }
                if !opts.is_empty() {
                    blocks.push(Block {
                        id: j.id,
                        gpus: j.gpus,
                        gen: group.gen,
                        opts,
                    });
                }
            }
        }

        let n_vars: usize = blocks.iter().map(|b| b.opts.len()).sum();
        let mut lp = Lp::new(n_vars);
        let mut var = 0usize;
        // Per-type capacity rows (constraints 23, 24 + the per-type GPU
        // capacity needed once types are disjoint pools).
        let mut cpu_rows: BTreeMap<GpuGen, Vec<(usize, f64)>> =
            BTreeMap::new();
        let mut mem_rows: BTreeMap<GpuGen, Vec<(usize, f64)>> =
            BTreeMap::new();
        let mut gpu_rows: BTreeMap<GpuGen, Vec<(usize, f64)>> =
            BTreeMap::new();
        // Per-job choice rows (constraint 25).
        let mut job_rows: BTreeMap<JobId, Vec<(usize, f64)>> = BTreeMap::new();
        let mut var_map: Vec<(usize, usize)> = Vec::new(); // var -> (block, opt)
        for (bi, b) in blocks.iter().enumerate() {
            for (oi, &(c, m, w)) in b.opts.iter().enumerate() {
                lp.set_objective(var, w);
                cpu_rows.entry(b.gen).or_default().push((var, c));
                mem_rows.entry(b.gen).or_default().push((var, m));
                gpu_rows.entry(b.gen).or_default().push((var, b.gpus as f64));
                job_rows.entry(b.id).or_default().push((var, 1.0));
                var_map.push((bi, oi));
                var += 1;
            }
        }
        for group in &cluster.groups {
            if let Some(row) = cpu_rows.remove(&group.gen) {
                lp.add(row, Op::Le, group.cluster.total_cpus());
            }
            if let Some(row) = mem_rows.remove(&group.gen) {
                lp.add(row, Op::Le, group.cluster.total_mem_gb());
            }
            if let Some(row) = gpu_rows.remove(&group.gen) {
                lp.add(row, Op::Le, group.cluster.total_gpus() as f64);
            }
        }
        for (_, row) in job_rows {
            lp.add(row, Op::Eq, 1.0);
        }

        let int_vars: Vec<usize> = (0..n_vars).collect();
        let sol = solve_ilp(&lp, &int_vars, IlpOptions::default()).ok()?;

        let mut chosen = BTreeMap::new();
        for (v, &(bi, oi)) in var_map.iter().enumerate() {
            if sol.x[v] > 0.5 {
                let b = &blocks[bi];
                let (c, m, w) = b.opts[oi];
                chosen.insert(b.id, (b.gen, c, m, w));
            }
        }
        Some(HetOptAllocation { chosen, objective: sol.objective, n_vars })
    }
}

impl HetMechanism for HetOpt {
    fn name(&self) -> &'static str {
        "het-opt"
    }

    /// Materialize the ILP allocation: place each job on its chosen type
    /// with the chosen demand via best-fit; fall back to proportional on
    /// that type if packing fails (the ILP ignores server boundaries, as
    /// in the homogeneous OPT).
    fn allocate(
        &self,
        cluster: &mut HeteroCluster,
        jobs: &[HetJobRequest<'_>],
    ) -> BTreeMap<JobId, HetGrant> {
        let Some(alloc) = self.solve_allocation(cluster, jobs) else {
            return BTreeMap::new();
        };
        let mut out = BTreeMap::new();
        for j in jobs {
            let Some(&(gen, c, m, _)) = alloc.chosen.get(&j.id) else {
                continue;
            };
            let group = cluster.group_mut(gen).expect("chosen group");
            let demand = DemandVector::new(j.gpus, c, m);
            let spec = group.cluster.spec;
            let prop = DemandVector::proportional(
                j.gpus,
                spec.cpus as f64 / spec.gpus as f64,
                spec.mem_gb / spec.gpus as f64,
            );
            for d in [demand, prop] {
                if let Some(p) = crate::mechanism::best_fit(&group.cluster, &d)
                {
                    group.cluster.place(j.id, p.clone());
                    out.insert(
                        j.id,
                        HetGrant {
                            gen,
                            grant: Grant { placement: p, demand: d },
                        },
                    );
                    break;
                }
            }
        }
        out
    }
}

/// Look up a heterogeneous mechanism by CLI name.
pub fn het_by_name(name: &str) -> Option<Box<dyn HetMechanism>> {
    match name {
        "het-proportional" | "het-prop" => Some(Box::new(HetProportional)),
        "het-tune" => Some(Box::new(HetTune)),
        "het-opt" => Some(Box::new(HetOpt)),
        _ => None,
    }
}

pub const ALL_HET_MECHANISMS: [&str; 3] =
    ["het-proportional", "het-tune", "het-opt"];

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::profiler::HeteroProfiler;
    use crate::job::{Job, ModelKind};

    fn setup(
        models: &[(u64, ModelKind, u32)],
    ) -> (HeteroCluster, Vec<Job>, Vec<HeteroSensitivity>) {
        let cluster = HeteroCluster::two_tier(1);
        let profiler = HeteroProfiler::noiseless(&cluster);
        let jobs: Vec<Job> = models
            .iter()
            .map(|&(id, m, g)| Job::new(JobId(id), m, g, 0.0, 3600.0))
            .collect();
        let sens: Vec<HeteroSensitivity> =
            jobs.iter().map(|j| profiler.profile(j)).collect();
        (cluster, jobs, sens)
    }

    fn requests<'a>(
        jobs: &'a [Job],
        sens: &'a [HeteroSensitivity],
    ) -> Vec<HetJobRequest<'a>> {
        jobs.iter()
            .zip(sens)
            .map(|(j, s)| HetJobRequest { id: j.id, gpus: j.gpus, sens: s })
            .collect()
    }

    #[test]
    fn het_tune_places_all_when_gpus_fit() {
        let (mut cluster, jobs, sens) = setup(&[
            (0, ModelKind::ResNet18, 4),
            (1, ModelKind::Gnmt, 4),
            (2, ModelKind::ShuffleNetV2, 4),
            (3, ModelKind::TransformerXl, 4),
        ]);
        let reqs = requests(&jobs, &sens);
        let grants = HetTune.allocate(&mut cluster, &reqs);
        assert_eq!(grants.len(), 4);
        assert!(cluster.check_consistency().is_ok());
        // No type hosts more GPUs than it has.
        assert_eq!(cluster.free_gpus(), 0);
    }

    #[test]
    fn het_tune_sends_compute_bound_jobs_to_fast_type() {
        // One compute-bound language job + one input-bound image job:
        // the language job should land on the V100 group.
        let (mut cluster, jobs, sens) = setup(&[
            (0, ModelKind::Gnmt, 8),
            (1, ModelKind::ShuffleNetV2, 8),
        ]);
        let reqs = requests(&jobs, &sens);
        let grants = HetTune.allocate(&mut cluster, &reqs);
        assert_eq!(grants[&JobId(0)].gen, GpuGen::V100, "gnmt on fast type");
        assert_eq!(grants[&JobId(1)].gen, GpuGen::P100);
    }

    #[test]
    fn fairness_floor_holds_for_every_grant() {
        let (mut cluster, jobs, sens) = setup(&[
            (0, ModelKind::ResNet18, 2),
            (1, ModelKind::AlexNet, 2),
            (2, ModelKind::Gnmt, 2),
            (3, ModelKind::M5, 2),
            (4, ModelKind::DeepSpeech, 4),
            (5, ModelKind::Lstm, 4),
        ]);
        let reqs = requests(&jobs, &sens);
        let grants = HetTune.allocate(&mut cluster, &reqs);
        for (j, s) in jobs.iter().zip(&sens) {
            let Some(g) = grants.get(&j.id) else { continue };
            let m = s.matrix(g.gen).unwrap();
            let got = m.throughput_at(g.grant.demand.cpus, g.grant.demand.mem_gb);
            assert!(
                got + 1e-9 >= s.fair_throughput(),
                "{:?}: {} < fair {}",
                j.id,
                got,
                s.fair_throughput()
            );
        }
    }

    #[test]
    fn het_opt_upper_bounds_het_tune() {
        let (mut cluster, jobs, sens) = setup(&[
            (0, ModelKind::ResNet18, 4),
            (1, ModelKind::Gnmt, 4),
            (2, ModelKind::AlexNet, 4),
            (3, ModelKind::Lstm, 4),
        ]);
        let reqs = requests(&jobs, &sens);
        let opt = HetOpt.solve_allocation(&cluster, &reqs).expect("ilp");
        let grants = HetTune.allocate(&mut cluster, &reqs);
        let tune_tput: f64 = jobs
            .iter()
            .zip(&sens)
            .filter_map(|(j, s)| {
                grants.get(&j.id).map(|g| {
                    s.matrix(g.gen)
                        .unwrap()
                        .throughput_at(g.grant.demand.cpus, g.grant.demand.mem_gb)
                })
            })
            .sum();
        assert!(
            opt.objective + 1e-6 >= tune_tput,
            "OPT {} must dominate TUNE {}",
            opt.objective,
            tune_tput
        );
    }

    #[test]
    fn het_proportional_is_type_blind() {
        let (mut cluster, jobs, sens) =
            setup(&[(0, ModelKind::Gnmt, 8), (1, ModelKind::Gnmt, 8)]);
        let reqs = requests(&jobs, &sens);
        let grants = HetProportional.allocate(&mut cluster, &reqs);
        // Two identical jobs, two identical-capacity types: both types
        // get used regardless of sensitivity.
        let gens: Vec<GpuGen> = grants.values().map(|g| g.gen).collect();
        assert_eq!(grants.len(), 2);
        assert_ne!(gens[0], gens[1]);
    }

    #[test]
    fn by_name_covers_all() {
        for n in ALL_HET_MECHANISMS {
            assert!(het_by_name(n).is_some(), "{n}");
        }
        assert!(het_by_name("warp-drive").is_none());
    }
}
