//! Figure 1: average JCT vs cluster load for Synergy vs GPU-proportional,
//! LAS and FIFO policies, 128 GPUs, Philly-derived single-GPU trace.
//!
//! Paper shape: Synergy-TUNE's curve stays flat to substantially higher
//! load; at high load the gap reaches ~3x.

mod common;

use common::{dynamic_trace, run_sim, steady_stats};
use synergy::trace::SPLIT_DEFAULT;
use synergy::util::bench::{row, section};

fn main() {
    let n_jobs = 2500;
    section("Figure 1: avg JCT vs load (128 GPUs, split 20/70/10, single-GPU)");
    for policy in ["las", "fifo"] {
        for mechanism in ["proportional", "tune"] {
            for load in [4.0, 6.0, 8.0, 9.0, 10.0, 11.0, 12.0] {
                let jobs =
                    dynamic_trace(n_jobs, load, SPLIT_DEFAULT, false, 101);
                let result = run_sim(16, policy, mechanism, jobs);
                let stats = steady_stats(&result);
                row(
                    "fig1",
                    &format!("{policy}/{mechanism}"),
                    load,
                    stats.avg_hrs(),
                    &format!("p99_h={:.2} n={}", stats.p99_hrs(), stats.n),
                );
            }
        }
    }
}
