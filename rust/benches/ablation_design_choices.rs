//! Design-choice ablations for Synergy-TUNE and the scheduler loop
//! (DESIGN.md §6 calls these decisions out; this bench quantifies them).
//!
//! 1. **Placement strategy** — §4.2's best-fit ("least amount of free
//!    resources just enough to fit") vs plain first-fit.
//! 2. **Victim selection** — largest-excess victims (fewest downgrades)
//!    vs first-found.
//! 3. **Round duration** — the paper schedules every ~5 minutes; sweep
//!    1–30 min to show the JCT/overhead tradeoff.
//! 4. **Profiler noise** — optimistic profiling measures a few noisy
//!    iterations (§3.1); sweep the noise level to show scheduling
//!    quality is robust to realistic measurement error.

mod common;

use synergy::sim::{SimConfig, Simulator};
use synergy::trace::{generate, Split, TraceConfig};
use synergy::util::bench::{row, section};

fn trace(seed: u64) -> Vec<synergy::job::Job> {
    generate(&TraceConfig {
        n_jobs: 400,
        split: Split::new(30, 50, 20),
        multi_gpu: true,
        jobs_per_hour: Some(7.0),
        seed,
    })
}

fn run(mechanism: &str, round_s: f64, noise: f64, seed: u64) -> f64 {
    let sim = Simulator::new(SimConfig {
        n_servers: 16,
        policy: "srtf".into(),
        mechanism: mechanism.into(),
        round_s,
        profile_noise: noise,
        ..Default::default()
    });
    let r = sim.run(trace(seed));
    assert_eq!(r.finished.len(), 400, "all jobs must finish");
    r.jct_stats().avg_hrs()
}

fn main() {
    // --- 1 & 2: packing strategy ablations ---------------------------------
    section("Ablation: TUNE placement & victim strategies (SRTF, 128 GPUs)");
    for mech in ["tune", "tune-first-fit", "tune-victim-first", "greedy"] {
        let mut avgs = Vec::new();
        for seed in [1u64, 2, 3] {
            avgs.push(run(mech, 300.0, 0.0, seed));
        }
        let mean = avgs.iter().sum::<f64>() / avgs.len() as f64;
        row("ablation/strategy", mech, mean, 0.0, "avg JCT h (3 seeds)");
    }

    // --- 3: round duration --------------------------------------------------
    section("Ablation: round duration (TUNE, SRTF)");
    for round_min in [1.0, 5.0, 10.0, 30.0] {
        let avg = run("tune", round_min * 60.0, 0.0, 1);
        row("ablation/round", &format!("{round_min}min"), round_min, avg, "avg JCT h");
    }

    // --- 4: profiler noise ----------------------------------------------------
    section("Ablation: profiling measurement noise (TUNE, SRTF)");
    for noise in [0.0, 0.03, 0.10, 0.25] {
        let avg = run("tune", 300.0, noise, 1);
        row("ablation/noise", &format!("sd{noise}"), noise, avg, "avg JCT h");
    }
}
