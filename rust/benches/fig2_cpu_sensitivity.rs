//! Figure 2a: per-epoch time vs CPU:GPU ratio for all ten models
//! (single-GPU training, dataset fully cached).
//!
//! Paper shape: image/speech models keep improving out to 9-24 cores;
//! language models are flat beyond 1 core.

use synergy::cluster::ServerSpec;
use synergy::job::{Task, ALL_MODELS};
use synergy::perf::PerfModel;
use synergy::util::bench::{row, section};

fn epoch_samples(task: Task) -> f64 {
    match task {
        Task::Image => 1_281_167.0,  // ImageNet
        Task::Language => 400_000.0, // WMT-class
        Task::Speech => 500_000.0,
    }
}

fn main() {
    let world = PerfModel::new(ServerSpec::default());
    section("Figure 2a: epoch time (h) vs CPUs per GPU (full cache)");
    for model in ALL_MODELS {
        for cpus in [1u32, 2, 3, 4, 6, 8, 9, 12, 16, 20, 24] {
            let t = world.epoch_time_s(
                model,
                1,
                cpus as f64,
                1000.0, // fully cached
                epoch_samples(model.task()),
            ) / 3600.0;
            row("fig2a", model.name(), cpus as f64, t, "");
        }
    }

    section("Figure 2a headline speedups");
    let tput = |m, c: f64| world.throughput(m, 1, c, 1000.0);
    use synergy::job::ModelKind::*;
    println!(
        "alexnet 3->12 cpus: {:.2}x (paper: 3.1x)",
        tput(AlexNet, 12.0) / tput(AlexNet, 3.0)
    );
    println!(
        "resnet18 3->9 cpus: {:.2}x (paper: 2.3x)",
        tput(ResNet18, 9.0) / tput(ResNet18, 3.0)
    );
    println!(
        "gnmt 1->12 cpus: {:.2}x (paper: ~1x)",
        tput(Gnmt, 12.0) / tput(Gnmt, 1.0)
    );
}
