//! L3 performance microbenches (§Perf deliverable): the scheduler hot
//! paths that bound deploy-mode round latency and simulator throughput.
//!
//! Targets (DESIGN.md §8): TUNE round < 1 s at 512 GPUs; profiler < 5 ms
//! per job; simulator >= 2k scheduled rounds/s on a 128-GPU trace.

use synergy::cluster::{Fleet, ServerSpec};
use synergy::job::{Job, JobId};
use synergy::mechanism::{JobRequest, Mechanism, Proportional, Tune};
use synergy::profiler::{OptimisticProfiler, Sensitivity};
use synergy::sim::{SimConfig, Simulator};
use synergy::trace::{generate, TraceConfig, SPLIT_DEFAULT};
use synergy::util::bench::{section, Bench};

fn main() {
    let spec = ServerSpec::default();
    let profiler = OptimisticProfiler::noiseless(spec);

    section("L3 hot path: profiler");
    let job = Job::new(JobId(0), synergy::job::ModelKind::ResNet18, 1, 0.0, 3600.0);
    Bench::default().iter("profile/resnet18_1gpu", || profiler.profile(&job));
    let job16 =
        Job::new(JobId(1), synergy::job::ModelKind::M5, 16, 0.0, 3600.0);
    Bench::default().iter("profile/m5_16gpu", || profiler.profile(&job16));

    section("L3 hot path: round allocation at 512 GPUs");
    let jobs: Vec<Job> = generate(&TraceConfig {
        n_jobs: 512,
        split: SPLIT_DEFAULT,
        multi_gpu: false,
        jobs_per_hour: None,
        seed: 42,
    });
    let sens: Vec<Sensitivity> =
        jobs.iter().map(|j| profiler.profile(j)).collect();
    let requests: Vec<JobRequest> = jobs
        .iter()
        .zip(sens.iter())
        .map(|(j, s)| JobRequest { id: j.id, gpus: j.gpus, sens: s })
        .collect();
    Bench::default().iter("tune/512_jobs_64_servers", || {
        let mut fleet = Fleet::homogeneous(spec, 64);
        Tune::default().allocate(&mut fleet, &requests)
    });
    Bench::default().iter("proportional/512_jobs_64_servers", || {
        let mut fleet = Fleet::homogeneous(spec, 64);
        Proportional.allocate(&mut fleet, &requests)
    });

    section("L3 hot path: end-to-end simulation (128 GPUs, 300 jobs)");
    let trace = generate(&TraceConfig {
        n_jobs: 300,
        split: SPLIT_DEFAULT,
        multi_gpu: true,
        jobs_per_hour: Some(6.0),
        seed: 9,
    });
    let b = Bench::heavy();
    let t = b.iter("simulate/300_jobs_128gpus_tune", || {
        Simulator::new(SimConfig {
            n_servers: 16,
            policy: "srtf".into(),
            mechanism: "tune".into(),
            ..Default::default()
        })
        .run(trace.clone())
    });
    // Report rounds/s for the §Perf log.
    let r = Simulator::new(SimConfig {
        n_servers: 16,
        policy: "srtf".into(),
        mechanism: "tune".into(),
        ..Default::default()
    })
    .run(trace.clone());
    println!(
        "simulator: {} rounds in {:?} median -> {:.0} rounds/s",
        r.rounds,
        t.median,
        r.rounds as f64 / t.median.as_secs_f64()
    );
}
