//! Figure 6 / Table 6: Philly-derived trace on 512 GPUs (64 servers),
//! split (20,70,10), multi-GPU demands.
//!
//! (a) avg JCT for SRTF / LAS / FIFO, Synergy vs GPU-proportional;
//! (b) short/long split with avg + p99 (SRTF);
//! (c) per-job speedup distribution (paper: up to ~9x, none slower).

mod common;

use common::{dynamic_trace_via_philly_reader, run_sim, steady_stats};
use synergy::job::Job;
use synergy::trace::SPLIT_DEFAULT;
use synergy::metrics::{per_job_speedups, split_short_long, JctStats};
use synergy::util::bench::{row, section};
use synergy::workload::{PhillyTraceConfig, PhillyTraceSource, WorkloadSource};
use std::collections::BTreeMap;

/// The Philly jobs for one run, always through the real CSV-reader path:
/// either `$SYNERGY_PHILLY_TRACE` (a real Philly-format CSV; λ-rescaled
/// via `--load-scale` semantics to keep the cluster saturated) or the
/// synthetic trace serialized + re-ingested through the reader.
fn philly_jobs(n_jobs: usize, load: f64, seed: u64) -> Vec<Job> {
    match std::env::var("SYNERGY_PHILLY_TRACE") {
        Ok(path) => {
            let mut src = PhillyTraceSource::new(PhillyTraceConfig {
                path,
                load_scale: std::env::var("SYNERGY_PHILLY_LOAD_SCALE")
                    .ok()
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(1.0),
                max_jobs: Some(n_jobs),
                seed,
                ..PhillyTraceConfig::default()
            })
            .expect("read $SYNERGY_PHILLY_TRACE");
            src.drain_jobs()
        }
        Err(_) => dynamic_trace_via_philly_reader(
            n_jobs,
            load,
            SPLIT_DEFAULT,
            true,
            seed,
        ),
    }
}

fn main() {
    let n_jobs = 4000; // subrange of the 8000-job trace; 1000 monitored
    let load = 36.0; // keeps 512 GPUs saturated

    section("Figure 6a / Table 6a: avg JCT on 512 GPUs (hrs)");
    let mut srtf_results = Vec::new();
    for policy in ["srtf", "las", "fifo"] {
        for mech in ["proportional", "tune"] {
            let jobs = philly_jobs(n_jobs, load, 606);
            let r = run_sim(64, policy, mech, jobs);
            let s = steady_stats(&r);
            row(
                "fig6a",
                &format!("{policy}/{mech}"),
                0.0,
                s.avg_hrs(),
                &format!("p99_h={:.2}", s.p99_hrs()),
            );
            if policy == "srtf" {
                srtf_results.push(r);
            }
        }
    }

    // (b) short/long split for SRTF.
    section("Table 6b: SRTF short(<4h)/long split");
    for (mech, r) in ["proportional", "tune"].iter().zip(&srtf_results) {
        let pairs: Vec<(f64, f64)> = r
            .finished
            .iter()
            .map(|f| (f.jct_s, f.duration_prop_s))
            .collect();
        let (short, long) = split_short_long(&pairs);
        let ss = JctStats::from_jcts(&short);
        let ls = JctStats::from_jcts(&long);
        row("fig6b", &format!("{mech}/short_avg_h"), 0.0, ss.avg_hrs(), "");
        row("fig6b", &format!("{mech}/short_p99_h"), 0.0, ss.p99_hrs(), "");
        row("fig6b", &format!("{mech}/long_avg_h"), 0.0, ls.avg_hrs(), "");
        row("fig6b", &format!("{mech}/long_p99_h"), 0.0, ls.p99_hrs(), "");
    }

    // (c) per-job speedup CDF (same jobs under both mechanisms).
    section("Figure 6c: per-job JCT speedup (tune vs proportional)");
    let by_id = |r: &synergy::sim::SimResult| -> BTreeMap<u64, f64> {
        r.finished.iter().map(|f| (f.id.0, f.jct_s)).collect()
    };
    let prop = by_id(&srtf_results[0]);
    let tune = by_id(&srtf_results[1]);
    let common_ids: Vec<u64> =
        prop.keys().filter(|k| tune.contains_key(k)).cloned().collect();
    let a: Vec<f64> = common_ids.iter().map(|k| tune[k]).collect();
    let b: Vec<f64> = common_ids.iter().map(|k| prop[k]).collect();
    let mut speedups = per_job_speedups(&a, &b);
    speedups.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let n = speedups.len();
    for pct in [1usize, 10, 25, 50, 75, 90, 99] {
        let idx = (pct * n / 100).min(n - 1);
        row("fig6c", "speedup_pctile", pct as f64, speedups[idx], "");
    }
    println!(
        "max per-job speedup: {:.1}x (paper: up to 9x); jobs slower than prop: {}",
        speedups.last().unwrap(),
        speedups.iter().filter(|&&s| s < 0.95).count()
    );
}
