//! Cluster-scale simulator throughput (ISSUE 4 perf deliverable): the
//! paper's evaluation scale — 512 GPUs, Philly-derived multi-GPU trace
//! of 8000 jobs (§5.1) — end to end through the memoized event core,
//! plus a mixed-generation (tri-type) fleet cell.
//!
//! ```bash
//! cargo bench --bench sim_scale
//! ```
//!
//! Writes `BENCH_sim.json` at the repo root: wall time, rounds/sec, and
//! the full-replan / prefix-resumed / memoized round split plus the mean
//! reused-prefix fraction per cell — the perf trajectory later PRs
//! track. Also asserts two invariants: under FIFO (time-stable keys) the
//! mechanism plans at most once per set change, so
//! `planned_rounds <= arrivals + completions + 1`; and under SRTF
//! (time-varying keys, where exact-match memoization almost never hits)
//! the prefix-resume tier engages at least once. A fourth cell reruns
//! the FIFO configuration with the ISSUE 6 telemetry recorder attached
//! and asserts the observer costs < 5% wall time and changes zero
//! scheduled bytes (`telemetry_overhead` in the JSON). A fifth cell
//! (ISSUE 8) drives 8192 GPUs × one million Google-derived jobs through
//! the streaming trace reader and the 4-way sharded planner, recording
//! rounds/sec and peak RSS (`VmHWM`) and asserting the peak stays
//! proportional to the trace (completed jobs retire their working
//! state); shrink it locally with `SYNERGY_SCALE_JOBS=10000`.
//!
//! Snapshot-design note (ISSUE 5): resume uses an **O(changes) undo
//! log** (per-pool journal of pre-mutation server counters + placement
//! deltas) rather than stride checkpoints. At this scale a stride
//! snapshot would copy 64 servers × counters per checkpoint per round
//! regardless of how little changed, while the journal's cost is
//! proportional to the steps actually rolled back — and the common SRTF
//! divergence is near the tail of the demand-sorted order, so rollbacks
//! are short. The `mean_reused_prefix` field quantifies exactly that.

use std::time::Duration;
use synergy::cluster::{GpuGen, ServerSpec, TypeSpec};
use synergy::sim::{SimConfig, SimResult, Simulator};
use synergy::telemetry::{TelemetryConfig, TelemetryRecorder};
use synergy::trace::{generate, TraceConfig, SPLIT_DEFAULT};
use synergy::util::bench::{section, Bench};
use synergy::util::json::Json;
use synergy::workload::{
    GoogleTraceConfig, GoogleTraceSource, WorkloadSource,
};

/// 64 × 8-GPU servers = the paper's 512-GPU cluster.
const N_SERVERS: usize = 64;
const N_JOBS: usize = 8_000;
/// Jobs/hour that keeps 512 GPUs saturated (fig6 uses the same).
const LOAD: f64 = 36.0;

struct Cell {
    name: &'static str,
    median_s: f64,
    result: SimResult,
}

fn run_cell(
    bench: &Bench,
    name: &'static str,
    n_jobs: usize,
    policy: &str,
    types: Option<Vec<TypeSpec>>,
    seed: u64,
) -> Cell {
    let trace = generate(&TraceConfig {
        n_jobs,
        split: SPLIT_DEFAULT,
        multi_gpu: true,
        jobs_per_hour: Some(LOAD),
        seed,
    });
    let mk_sim = || {
        Simulator::new(SimConfig {
            n_servers: N_SERVERS,
            policy: policy.into(),
            mechanism: "tune".into(),
            types: types.clone(),
            ..Default::default()
        })
    };
    // Keep the last timed run's result (runs are deterministic, and one
    // 512-GPU × 8k-job simulation is too expensive to repeat just for
    // the stats).
    let mut last: Option<SimResult> = None;
    let t = bench.iter(name, || last = Some(mk_sim().run(trace.clone())));
    let result = last.expect("bench ran at least once");
    assert_eq!(result.finished.len(), n_jobs, "{name}: all jobs finish");
    Cell { name, median_s: t.median.as_secs_f64(), result }
}

fn cell_json(c: &Cell) -> Json {
    let r = &c.result;
    // Mean reused-prefix fraction across planned rounds: the share of
    // per-job planning steps served from checkpoints instead of
    // replayed (0 when nothing planned).
    let reused_frac = if r.plan_steps_total > 0 {
        r.plan_steps_reused as f64 / r.plan_steps_total as f64
    } else {
        0.0
    };
    Json::obj(vec![
        ("cell", Json::str(c.name)),
        ("jobs", Json::num(r.finished.len() as f64)),
        ("wall_s", Json::num(c.median_s)),
        ("rounds", Json::num(r.rounds as f64)),
        ("planned_rounds", Json::num(r.planned_rounds as f64)),
        ("resumed_rounds", Json::num(r.resumed_rounds as f64)),
        (
            "full_replan_rounds",
            Json::num((r.planned_rounds - r.resumed_rounds) as f64),
        ),
        (
            "memoized_rounds",
            Json::num((r.rounds - r.planned_rounds) as f64),
        ),
        ("reused_steps", Json::num(r.plan_steps_reused as f64)),
        ("total_steps", Json::num(r.plan_steps_total as f64)),
        ("mean_reused_prefix", Json::num(reused_frac)),
        ("rounds_per_s", Json::num(r.rounds as f64 / c.median_s)),
        (
            "planned_rounds_per_s",
            Json::num(r.planned_rounds as f64 / c.median_s),
        ),
        ("makespan_days", Json::num(r.makespan_s / 86_400.0)),
    ])
}

/// Peak resident set (`VmHWM`) in MB from `/proc/self/status`; 0.0 when
/// unavailable (non-Linux), in which case the RSS assert is skipped.
fn peak_rss_mb() -> f64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0.0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0.0);
            return kb / 1024.0;
        }
    }
    0.0
}

/// Deterministically synthesize a 2019-format instance-events document:
/// one SUBMIT/SCHEDULE/FINISH triple per collection, arrivals at 1/s,
/// 1–4 GPUs (normalized CPU × the default ×8 multiplier), 10–50 min
/// durations — ~75% offered load on the 8192-GPU tri-gen fleet.
fn synth_google_trace(n_jobs: usize) -> String {
    use std::fmt::Write as _;
    // splitmix64: a pure function of the index, so the document (and
    // every schedule derived from it) is bit-stable across runs/hosts.
    fn mix(mut x: u64) -> u64 {
        x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^ (x >> 31)
    }
    let mut out = String::with_capacity(n_jobs * 96 + 64);
    out.push_str("time,type,collection_id,cpus,user\n");
    for i in 0..n_jobs as u64 {
        let h = mix(i + 1);
        // Normalized CPU in [0.05, 0.45): ceil(×8) = 1–4 GPUs.
        let cpus = 0.05 + (h % 4_000) as f64 / 10_000.0;
        let dur_us = (600 + mix(h) % 2_400) * 1_000_000;
        let submit_us = i * 1_000_000;
        let schedule_us = submit_us + 1_000_000;
        let finish_us = schedule_us + dur_us;
        let user = h % 50;
        let _ = writeln!(out, "{submit_us},0,{i},{cpus:.4},u{user}");
        let _ = writeln!(out, "{schedule_us},3,{i},{cpus:.4},u{user}");
        let _ = writeln!(out, "{finish_us},6,{i},{cpus:.4},u{user}");
    }
    out
}

fn main() {
    let bench = Bench {
        warmup_iters: 1,
        min_iters: 3,
        max_iters: 10,
        budget: Duration::from_secs(60),
    };

    section("sim_scale: 512 GPUs × 8000 Philly-derived jobs");
    // FIFO cell: time-stable policy keys — the planned-round bound is a
    // hard invariant of the memoization (arrivals + completions + 1).
    let fifo = run_cell(&bench, "sim/512gpu_8k_fifo_tune", N_JOBS, "fifo", None, 512);
    assert!(
        fifo.result.planned_rounds <= 2 * N_JOBS + 1,
        "memoization must engage: {} planned rounds > arrivals + \
         completions + 1 = {}",
        fifo.result.planned_rounds,
        2 * N_JOBS + 1
    );
    // SRTF cell: time-varying keys — exact-match memoization engages
    // only when the runnable sequence genuinely repeats, so this is the
    // cell the prefix-resume tier exists for. It must engage: remaining-
    // time reorders shift the sequence without changing the demand-
    // sorted pool order, so checkpointed prefixes get reused.
    let srtf =
        run_cell(&bench, "sim/512gpu_8k_srtf_tune", N_JOBS, "srtf", None, 512);
    assert!(
        srtf.result.resumed_rounds >= 1,
        "prefix resume must engage on the SRTF cell: {} planned rounds, \
         0 resumed",
        srtf.result.planned_rounds
    );

    section("sim_scale: tri-type 512-GPU fleet (K80 + P100 + V100)");
    let spec = ServerSpec::default();
    let tri = vec![
        TypeSpec { gen: GpuGen::K80, spec, machines: 22 },
        TypeSpec { gen: GpuGen::P100, spec, machines: 21 },
        TypeSpec { gen: GpuGen::V100, spec, machines: 21 },
    ];
    let tri_cell = run_cell(
        &bench,
        "sim/512gpu_tritype_4k_fifo_tune",
        N_JOBS / 2,
        "fifo",
        Some(tri),
        513,
    );
    assert!(
        tri_cell.result.planned_rounds <= 2 * (N_JOBS / 2) + 1,
        "tri-type memoization must engage: {} planned rounds",
        tri_cell.result.planned_rounds
    );

    section("sim_scale: telemetry overhead (recorder on, FIFO cell rerun)");
    // Same trace + config as the FIFO cell, with the ISSUE 6 recorder
    // attached: the delta now is exactly the telemetry hot-path cost
    // (O(pools + tenants) sampling per round + delta encoding).
    let telem_trace = generate(&TraceConfig {
        n_jobs: N_JOBS,
        split: SPLIT_DEFAULT,
        multi_gpu: true,
        jobs_per_hour: Some(LOAD),
        seed: 512,
    });
    let mut telem_last: Option<(SimResult, usize, usize)> = None;
    let telem_t = bench.iter("sim/512gpu_8k_fifo_tune_telemetry", || {
        let mut rec = TelemetryRecorder::new(TelemetryConfig::default());
        let r = Simulator::new(SimConfig {
            n_servers: N_SERVERS,
            policy: "fifo".into(),
            mechanism: "tune".into(),
            ..Default::default()
        })
        .run_with_telemetry(telem_trace.clone(), Some(&mut rec));
        telem_last = Some((r, rec.n_rounds(), rec.encoded_bytes()));
    });
    let (telem_result, telem_rounds, telem_bytes) =
        telem_last.expect("bench ran at least once");
    // Zero-scheduled-bytes rule, asserted at evaluation scale too.
    assert_eq!(
        telem_result.metrics_json(true, false),
        fifo.result.metrics_json(true, false),
        "telemetry changed the scheduled bytes at 512 GPUs × 8k jobs"
    );
    assert_eq!(telem_rounds, telem_result.rounds);
    let telem_cell = Cell {
        name: "sim/512gpu_8k_fifo_tune_telemetry",
        median_s: telem_t.median.as_secs_f64(),
        result: telem_result,
    };
    let overhead_pct =
        (telem_cell.median_s / fifo.median_s - 1.0) * 100.0;
    println!(
        "telemetry overhead: {:.2}s -> {:.2}s ({overhead_pct:+.2}%), \
         {telem_bytes} encoded bytes ({:.1} B/round)",
        fifo.median_s,
        telem_cell.median_s,
        telem_bytes as f64 / telem_cell.result.rounds.max(1) as f64,
    );
    assert!(
        overhead_pct < 5.0,
        "telemetry must stay under 5% rounds/sec overhead, measured \
         {overhead_pct:.2}%"
    );

    section("sim_scale: 8192 GPUs × 1M Google-derived jobs (sharded planner)");
    // ISSUE 8 scale cell: a million-collection 2019-format trace
    // streamed through `GoogleTraceSource`, scheduled on a 1024-server
    // tri-generation fleet with the planner fanned out over 4 shards.
    // One iteration — the run is deterministic and dominates the bench
    // budget. `SYNERGY_SCALE_JOBS` shrinks the trace for local smokes.
    let scale_jobs: usize = std::env::var("SYNERGY_SCALE_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);
    let ingest_t0 = std::time::Instant::now();
    let google_text = synth_google_trace(scale_jobs);
    let mut src = GoogleTraceSource::from_str(
        &google_text,
        &GoogleTraceConfig {
            path: "<synthetic>".into(),
            ..GoogleTraceConfig::default()
        },
    )
    .expect("synthetic google trace parses");
    drop(google_text); // bench scaffolding, not resident simulator state
    let scale_trace = src.drain_jobs();
    let ingest_s = ingest_t0.elapsed().as_secs_f64();
    assert_eq!(scale_trace.len(), scale_jobs, "every collection emits a job");
    println!(
        "google ingest: {scale_jobs} jobs in {ingest_s:.2}s \
         ({:.0} jobs/s)",
        scale_jobs as f64 / ingest_s
    );
    let scale_types = vec![
        TypeSpec { gen: GpuGen::K80, spec, machines: 342 },
        TypeSpec { gen: GpuGen::P100, spec, machines: 341 },
        TypeSpec { gen: GpuGen::V100, spec, machines: 341 },
    ];
    let scale_bench = Bench {
        warmup_iters: 0,
        min_iters: 1,
        max_iters: 1,
        budget: Duration::ZERO,
    };
    // Move the trace in (one timed iteration) instead of cloning a
    // million-job vector — peak RSS is part of what this cell reports.
    let mut scale_input = Some(scale_trace);
    let mut scale_last: Option<SimResult> = None;
    let scale_t =
        scale_bench.iter("sim/8192gpu_1m_google_fifo_tune_shards4", || {
            scale_last = Some(
                Simulator::new(SimConfig {
                    n_servers: 1024,
                    policy: "fifo".into(),
                    mechanism: "tune".into(),
                    types: Some(scale_types.clone()),
                    shards: 4,
                    ..Default::default()
                })
                .run(scale_input.take().expect("single iteration")),
            );
        });
    let scale_result = scale_last.expect("bench ran once");
    assert_eq!(
        scale_result.finished.len(),
        scale_jobs,
        "scale cell must drain the trace"
    );
    let peak_mb = peak_rss_mb();
    // Satellite (b) proportionality bound: completed jobs retire their
    // working state (the Sensitivity box collapses to one word), so
    // resident memory is the dense per-job trace slab (~a hundred bytes
    // a job) plus O(running jobs) — a 1M-job run fits comfortably under
    // ~0.5 GB of fixed overhead + ~1.2 KB/job. A leak of per-completion
    // state blows through this long before the run ends.
    let rss_bound_mb = 512.0 + scale_jobs as f64 * 1.2e-3;
    println!(
        "scale cell: peak RSS {peak_mb:.0} MB (bound {rss_bound_mb:.0} MB)"
    );
    if peak_mb > 0.0 {
        assert!(
            peak_mb < rss_bound_mb,
            "peak RSS must stay proportional to the trace: {peak_mb:.0} MB \
             >= {rss_bound_mb:.0} MB for {scale_jobs} jobs"
        );
    }
    let scale_cell = Cell {
        name: "sim/8192gpu_1m_google_fifo_tune_shards4",
        median_s: scale_t.median.as_secs_f64(),
        result: scale_result,
    };
    let scale_json = {
        let r = &scale_cell.result;
        Json::obj(vec![
            ("cell", Json::str(scale_cell.name)),
            ("jobs", Json::num(r.finished.len() as f64)),
            ("gpus", Json::num(8192.0)),
            ("shards", Json::num(4.0)),
            ("wall_s", Json::num(scale_cell.median_s)),
            ("ingest_s", Json::num(ingest_s)),
            ("rounds", Json::num(r.rounds as f64)),
            ("planned_rounds", Json::num(r.planned_rounds as f64)),
            (
                "memoized_rounds",
                Json::num((r.rounds - r.planned_rounds) as f64),
            ),
            (
                "rounds_per_s",
                Json::num(r.rounds as f64 / scale_cell.median_s),
            ),
            ("makespan_days", Json::num(r.makespan_s / 86_400.0)),
            ("peak_rss_mb", Json::num(peak_mb)),
            ("rss_bound_mb", Json::num(rss_bound_mb)),
        ])
    };

    for c in [&fifo, &srtf, &tri_cell, &telem_cell, &scale_cell] {
        let r = &c.result;
        println!(
            "{}: {:.2}s wall, {} rounds ({} full replans / {} resumed / \
             {} memoized), reused prefix {:.0}%, {:.0} rounds/s",
            c.name,
            c.median_s,
            r.rounds,
            r.planned_rounds - r.resumed_rounds,
            r.resumed_rounds,
            r.rounds - r.planned_rounds,
            if r.plan_steps_total > 0 {
                100.0 * r.plan_steps_reused as f64 / r.plan_steps_total as f64
            } else {
                0.0
            },
            r.rounds as f64 / c.median_s,
        );
    }

    // Persist the perf trajectory for later PRs.
    let doc = Json::obj(vec![
        ("bench", Json::str("sim_scale")),
        ("gpus", Json::num((N_SERVERS * 8) as f64)),
        (
            "cells",
            Json::arr(vec![
                cell_json(&fifo),
                cell_json(&srtf),
                cell_json(&tri_cell),
                cell_json(&telem_cell),
                scale_json,
            ]),
        ),
        (
            "telemetry_overhead",
            Json::obj(vec![
                ("baseline_cell", Json::str("sim/512gpu_8k_fifo_tune")),
                ("wall_s_off", Json::num(fifo.median_s)),
                ("wall_s_on", Json::num(telem_cell.median_s)),
                ("overhead_pct", Json::num(overhead_pct)),
                ("encoded_bytes", Json::num(telem_bytes as f64)),
                (
                    "bytes_per_round",
                    Json::num(
                        telem_bytes as f64
                            / telem_cell.result.rounds.max(1) as f64,
                    ),
                ),
            ]),
        ),
    ])
    .encode();
    let out_path = format!("{}/../BENCH_sim.json", env!("CARGO_MANIFEST_DIR"));
    match std::fs::write(&out_path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
