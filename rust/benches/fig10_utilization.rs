//! Figure 10: cluster resource utilization.
//!
//! (a) GPU allocation over time for the all-sensitive split (50,0,50) at
//!     5.5 jobs/hr: GREEDY strands GPUs, TUNE keeps them busy;
//! (b) CPU utilization at low load: proportional leaves CPUs idle (~60%),
//!     TUNE pushes them to ~90%.

mod common;

use common::{dynamic_trace, run_sim};
use synergy::trace::SPLIT_WORST;
use synergy::util::bench::{row, section};

fn main() {
    // (a) GPU utilization over time, worst-case split, overload.
    section("Figure 10a: GPU utilization over time (split 50/0/50, 5.5 jobs/hr)");
    for mech in ["greedy", "tune"] {
        let jobs = dynamic_trace(1200, 5.5, SPLIT_WORST, true, 1000);
        let r = run_sim(16, "fifo", mech, jobs);
        // Sample ~20 points across the run.
        let samples = &r.utilization.samples;
        let step = (samples.len() / 20).max(1);
        for s in samples.iter().step_by(step) {
            row(
                "fig10a",
                &format!("{mech}/gpu_util"),
                s.time_s / 3600.0,
                s.gpu_util * 100.0,
                "",
            );
        }
        println!(
            "{mech}: mean GPU util {:.1}%  mean CPU used (busy) {:.1}%",
            r.utilization.mean_gpu_util() * 100.0,
            r.utilization.mean_cpu_used_busy() * 100.0
        );
    }

    // (b) CPU utilization at low load.
    section("Figure 10b: CPU utilization at low load (split 20/70/10, 4 jobs/hr)");
    for mech in ["proportional", "tune"] {
        let jobs =
            dynamic_trace(300, 8.0, synergy::trace::Split::new(50, 30, 20), true, 1001);
        let r = run_sim(16, "fifo", mech, jobs);
        // The paper plots CPU *utilization* — cores actively
        // pre-processing — not allocation (proportional always allocates
        // everything at load; stalled jobs just cannot use it).
        row(
            "fig10b",
            &format!("{mech}/mean_cpu_used"),
            0.0,
            r.utilization.mean_cpu_used_busy() * 100.0,
            &format!("avg_jct_h={:.2}", r.jct_stats().avg_hrs()),
        );
    }
    println!("(paper: proportional ~60% CPU util, TUNE ~90%, 1.5x lower avg JCT)");
}
