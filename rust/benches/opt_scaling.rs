//! §5.6: Synergy-OPT solve time vs cluster size, against Synergy-TUNE's
//! per-round planning time, plus the TUNE-within-10%-of-OPT check.
//!
//! Paper: OPT's per-round time grows super-linearly with cluster size
//! ("increases exponentially"); TUNE stays ~1 second; TUNE's aggregate
//! throughput is within 10% of OPT and ~200x faster to compute at
//! 128 GPUs.

use synergy::cluster::{Fleet, ServerSpec};
use synergy::job::Job;
use synergy::mechanism::{JobRequest, Mechanism, Opt, Tune};
use synergy::profiler::{OptimisticProfiler, Sensitivity};
use synergy::trace::{generate, TraceConfig, SPLIT_DEFAULT};
use synergy::util::bench::{row, section, Bench};

fn build_requests<'a>(
    jobs: &'a [Job],
    sens: &'a [Sensitivity],
) -> Vec<JobRequest<'a>> {
    jobs.iter()
        .zip(sens.iter())
        .map(|(j, s)| JobRequest { id: j.id, gpus: j.gpus, sens: s })
        .collect()
}

fn main() {
    let spec = ServerSpec::default();
    let profiler = OptimisticProfiler::noiseless(spec);

    // Sweep capped at 256 GPUs: the exact ILP's super-linear growth is
    // unambiguous by then (16→256 GPUs: 14 ms → ~2.6 min per round) and
    // the paper's own §5.6 measurements use a 128-GPU cluster.
    section("§5.6: per-round solve time vs cluster size");
    for n_servers in [2usize, 4, 8, 16, 32] {
        let n_gpus = n_servers * 8;
        // A full round: one 1-GPU job per GPU.
        let jobs: Vec<Job> = generate(&TraceConfig {
            n_jobs: n_gpus,
            split: SPLIT_DEFAULT,
            multi_gpu: false,
            jobs_per_hour: None,
            seed: 77,
        });
        let sens: Vec<Sensitivity> =
            jobs.iter().map(|j| profiler.profile(j)).collect();
        let requests = build_requests(&jobs, &sens);

        let bench = Bench {
            warmup_iters: 1,
            min_iters: if n_servers > 16 { 1 } else { 3 },
            max_iters: if n_servers > 16 { 1 } else { 10 },
            budget: std::time::Duration::from_secs(2),
        };
        let opt = Opt::default();
        let tune_t = bench.iter(&format!("tune/{n_gpus}gpus"), || {
            let mut fleet = Fleet::homogeneous(spec, n_servers);
            Tune::default().allocate(&mut fleet, &requests)
        });
        let opt_t = bench.iter(
            &format!(
                "opt{}/{n_gpus}gpus",
                if opt.relax_only { "-relaxed" } else { "" }
            ),
            || {
                let fleet = Fleet::homogeneous(spec, n_servers);
                opt.solve_allocation(&fleet, &requests)
            },
        );
        row(
            "opt_scaling",
            "speedup_tune_over_opt",
            n_gpus as f64,
            opt_t.median.as_secs_f64() / tune_t.median.as_secs_f64(),
            &format!(
                "tune={:?} opt={:?}",
                tune_t.median, opt_t.median
            ),
        );

        // Quality: TUNE aggregate throughput vs OPT objective.
        let mut fleet = Fleet::homogeneous(spec, n_servers);
        let grants = Tune::default().allocate(&mut fleet, &requests);
        let tune_tput: f64 = requests
            .iter()
            .filter_map(|r| grants.get(&r.id).map(|g| (r, g)))
            .map(|(r, g)| {
                r.sens
                    .matrix(g.gen)
                    .unwrap()
                    .throughput_at(g.demand.cpus, g.demand.mem_gb)
            })
            .sum();
        let fleet2 = Fleet::homogeneous(spec, n_servers);
        if let Some(alloc) = opt.solve_allocation(&fleet2, &requests) {
            row(
                "opt_quality",
                "tune_over_opt_tput",
                n_gpus as f64,
                tune_tput / alloc.objective,
                &format!("(paper: >= 0.9)"),
            );
        }
    }
}
