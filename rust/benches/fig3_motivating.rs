//! Figure 3 / Tables 1-3: the motivating example — four 4-GPU jobs on two
//! servers, GPU-proportional vs resource-sensitive, per-job epoch time and
//! the average-JCT improvement (paper: ~1.5x).

use synergy::cluster::{Fleet, ServerSpec};
use synergy::coordinator::RoundPlanner;
use synergy::job::{Job, JobId, ModelKind, Task};
use synergy::mechanism::by_name;
use synergy::perf::PerfModel;
use synergy::policy::Fifo;
use synergy::profiler::{OptimisticProfiler, Sensitivity};
use synergy::util::bench::{row, section};

fn epoch_samples(task: Task) -> f64 {
    match task {
        Task::Image => 1_281_167.0,
        Task::Language => 400_000.0,
        Task::Speech => 500_000.0,
    }
}

fn main() {
    let spec = ServerSpec::default();
    let world = PerfModel::new(spec);
    let profiler = OptimisticProfiler::noiseless(spec);
    let jobs: Vec<Job> = [
        (1u64, ModelKind::ResNet18),
        (2, ModelKind::M5),
        (3, ModelKind::TransformerXl),
        (4, ModelKind::Gnmt),
    ]
    .iter()
    .map(|&(id, m)| Job::new(JobId(id), m, 4, 0.0, 3600.0))
    .collect();

    let mut avgs = Vec::new();
    for mech in ["proportional", "tune"] {
        section(&format!("Fig 3 / Table {}: {mech}", if mech == "tune" { 3 } else { 2 }));
        let mut fleet = Fleet::homogeneous(spec, 2);
        let ctxs: Vec<Sensitivity> = jobs
            .iter()
            .map(|j| profiler.profile(j))
            .collect();
        let refs: Vec<(&Job, &Sensitivity)> =
            jobs.iter().zip(ctxs.iter()).collect();
        let planner =
            RoundPlanner::new(Box::new(Fifo), by_name(mech).unwrap());
        let plan = planner.plan(&mut fleet, &refs, 0.0);
        let mut total = 0.0;
        for j in &jobs {
            let g = &plan.grants[&j.id];
            let tput = world.throughput(
                j.model, j.gpus, g.demand.cpus, g.demand.mem_gb,
            );
            let epoch_h = epoch_samples(j.model.task()) / tput / 3600.0;
            total += epoch_h;
            row(
                "fig3",
                &format!("{mech}/{}", j.model.name()),
                j.id.0 as f64,
                epoch_h,
                &format!(
                    "cpu={:.0} mem={:.0}GB",
                    g.demand.cpus, g.demand.mem_gb
                ),
            );
        }
        avgs.push(total / jobs.len() as f64);
    }
    println!(
        "\naverage epoch-time improvement: {:.2}x (paper: ~1.5x)",
        avgs[0] / avgs[1]
    );
}
