//! Figure 12: impact of the server CPU:GPU ratio (FIFO, single-GPU trace,
//! 128 GPUs, load sweep; ratios 3-6 matching the server SKUs of Table 2b).
//!
//! Paper shape: richer servers shrink the TUNE-vs-proportional gap, but
//! at 9 jobs/hr TUNE still wins 3.4x / 3x / 2.2x / 1.8x for ratios
//! 3 / 4 / 5 / 6.

mod common;

use common::{dynamic_trace, run_sim_ref, steady_stats};
use synergy::cluster::ServerSpec;
use synergy::trace::SPLIT_DEFAULT;
use synergy::util::bench::{row, section};

fn main() {
    for ratio in [3u32, 4, 5, 6] {
        section(&format!("Figure 12: CPU:GPU ratio {ratio}"));
        let spec = ServerSpec::with_cpu_ratio(ratio);
        let mut at9 = Vec::new();
        for mech in ["proportional", "tune"] {
            for load in [5.0, 7.0, 9.0, 11.0] {
                let jobs =
                    dynamic_trace(2000, load, SPLIT_DEFAULT, false, 1200);
                // Durations stay defined against the ratio-3 reference
                // SKU (paper §5.1) so richer servers genuinely speed up
                // the proportional baseline.
                let r = run_sim_ref(
                    spec,
                    Some(ServerSpec::with_cpu_ratio(3)),
                    16,
                    "fifo",
                    mech,
                    jobs,
                );
                let s = steady_stats(&r);
                row(
                    "fig12",
                    &format!("ratio{ratio}/{mech}"),
                    load,
                    s.avg_hrs(),
                    "",
                );
                if load == 11.0 {
                    at9.push(s.avg_hrs());
                }
            }
        }
        if at9.len() == 2 {
            println!(
                "ratio {ratio} @ 11 jobs/hr: tune {:.2}x better (paper: {}x)",
                at9[0] / at9[1],
                match ratio {
                    3 => "3.4",
                    4 => "3.0",
                    5 => "2.2",
                    _ => "1.8",
                }
            );
        }
    }
}
