//! Appendix A.2 extension bench: heterogeneous GPU clusters through the
//! one type-generic stack.
//!
//! The paper's appendix formulates Synergy for clusters with several GPU
//! generations but does not evaluate it; this bench supplies the
//! evaluation for our implementation:
//!
//! 1. **Static drain** — a mixed workload on a P100+V100 fleet: TUNE
//!    (type-affine assignment + per-pool Synergy-TUNE) vs the type-blind
//!    proportional baseline, and the A.2.3 ILP upper bound on one
//!    round's aggregate throughput.
//! 2. **Dynamic load sweep** — avg JCT vs arrival rate for both
//!    mechanisms.
//! 3. **Profiling-cost accounting** — the extra dimension's cost
//!    (A.2: "at an additional profiling cost").

mod common;

use common::dynamic_trace;
use synergy::cluster::Fleet;
use synergy::hetero::{HeteroSimConfig, HeteroSimResult, HeteroSimulator};
use synergy::job::Job;
use synergy::mechanism::{JobRequest, Mechanism, Opt, Tune};
use synergy::profiler::{OptimisticProfiler, Sensitivity};
use synergy::trace::{generate, Split, TraceConfig};
use synergy::util::bench::{row, section};

fn run_het(mechanism: &str, jobs: Vec<Job>) -> HeteroSimResult {
    HeteroSimulator::new(HeteroSimConfig {
        mechanism: mechanism.into(),
        policy: "srtf".into(),
        ..Default::default()
    })
    .run(jobs)
}

fn main() {
    // --- 1. static drain ---------------------------------------------------
    section("Hetero A.2: static drain, 128 GPUs (64 P100 + 64 V100)");
    let jobs = generate(&TraceConfig {
        n_jobs: 160,
        split: Split::new(30, 50, 20),
        multi_gpu: true,
        jobs_per_hour: None,
        seed: 11,
    });
    for mech in ["het-proportional", "het-tune"] {
        let r = run_het(mech, jobs.clone());
        let s = r.jct_stats();
        row("hetero/static", mech, s.avg_hrs(), s.p99_hrs(), "avg/p99 h");
    }

    // --- 2. dynamic load sweep ----------------------------------------------
    section("Hetero A.2: dynamic load sweep (SRTF, multi-GPU)");
    for load in [4.0, 6.0, 8.0] {
        let jobs = dynamic_trace(800, load, Split::new(30, 50, 20), true, 77);
        let mut avg = Vec::new();
        for mech in ["het-proportional", "het-tune"] {
            let r = run_het(mech, jobs.clone());
            let s = r.jct_stats();
            row("hetero/load", mech, load, s.avg_hrs(), "avg h");
            avg.push(s.avg_hrs());
        }
        println!(
            "  load {load}: het-tune {:.2}x better than type-blind",
            avg[0] / avg[1]
        );
    }

    // --- 3. one-round ILP upper bound ----------------------------------------
    section("Hetero A.2.3: ILP upper bound vs TUNE (one round)");
    let mut fleet = Fleet::two_tier(4);
    let profiler = OptimisticProfiler::noiseless_fleet(&fleet);
    let round_jobs = generate(&TraceConfig {
        n_jobs: 14,
        split: Split::new(40, 40, 20),
        multi_gpu: true,
        jobs_per_hour: None,
        seed: 5,
    });
    let sens: Vec<Sensitivity> =
        round_jobs.iter().map(|j| profiler.profile(j)).collect();
    let reqs: Vec<JobRequest<'_>> = round_jobs
        .iter()
        .zip(&sens)
        .map(|(j, s)| JobRequest { id: j.id, gpus: j.gpus, sens: s })
        .collect();
    let t0 = std::time::Instant::now();
    let opt = Opt::default().solve_allocation(&fleet, &reqs).expect("ilp");
    let opt_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = std::time::Instant::now();
    let grants = Tune::default().allocate(&mut fleet, &reqs);
    let tune_ms = t0.elapsed().as_secs_f64() * 1e3;
    let tune_tput: f64 = round_jobs
        .iter()
        .zip(&sens)
        .filter_map(|(j, s)| {
            grants.get(&j.id).map(|g| {
                s.matrix(g.gen)
                    .unwrap()
                    .throughput_at(g.demand.cpus, g.demand.mem_gb)
            })
        })
        .sum();
    row("hetero/opt", "ilp-objective", opt.objective, opt_ms, "tput / ms");
    row("hetero/opt", "tune", tune_tput, tune_ms, "tput / ms");
    println!(
        "  tune achieves {:.1}% of the ILP bound ({} ILP vars)",
        100.0 * tune_tput / opt.objective,
        opt.n_vars
    );

    // --- 4. profiling cost ----------------------------------------------------
    section("Hetero A.2: profiling cost (2 types vs 1)");
    let het = run_het("het-tune", jobs.clone());
    let hom = common::run_sim(16, "srtf", "tune", jobs);
    row(
        "hetero/profiling",
        "minutes",
        het.profiling_minutes,
        hom.profiling_minutes,
        "het vs homogeneous",
    );
}
