//! Table 5: the physical-cluster experiments, replayed on the simulator
//! (the deploy-mode half runs via `examples/deploy_cluster`).
//!
//! (1) static trace, 100 jobs, split (60,30,10), FIFO -> makespan;
//! (2) dynamic trace at full load, split (30,60,10), SRTF -> avg + p99 JCT.
//! 32 GPUs across 4 servers. Paper: TUNE improves makespan 1.4x, avg JCT
//! 1.5x, p99 JCT 2x; OPT adds a few % more.

mod common;

use common::{dynamic_trace, run_sim, static_trace, steady_stats};
use synergy::trace::{SPLIT_DYNAMIC, SPLIT_STATIC};
use synergy::util::bench::{row, section};

fn main() {
    // (1) Static FIFO makespan.
    section("Table 5 (static, FIFO, split 60/30/10): makespan");
    let mut makespans = Vec::new();
    for mech in ["proportional", "tune", "opt"] {
        let jobs = static_trace(100, SPLIT_STATIC, true, 55);
        let r = run_sim(4, "fifo", mech, jobs);
        let h = r.makespan_s / 3600.0;
        makespans.push(h);
        row("table5", &format!("fifo/{mech}/makespan_h"), 0.0, h, "");
    }
    println!(
        "makespan improvement tune vs proportional: {:.2}x (paper: 1.4x)",
        makespans[0] / makespans[1]
    );

    // (2) Dynamic SRTF at full load.
    section("Table 5 (dynamic, SRTF, split 30/60/10): avg & p99 JCT");
    let mut avg = Vec::new();
    let mut p99 = Vec::new();
    for mech in ["proportional", "tune", "opt"] {
        // load chosen to keep the 32-GPU cluster saturated
        let jobs = dynamic_trace(300, 3.0, SPLIT_DYNAMIC, true, 56);
        let r = run_sim(4, "srtf", mech, jobs);
        let s = steady_stats(&r);
        avg.push(s.avg_hrs());
        p99.push(s.p99_hrs());
        row("table5", &format!("srtf/{mech}/avg_jct_h"), 0.0, s.avg_hrs(), "");
        row("table5", &format!("srtf/{mech}/p99_jct_h"), 0.0, s.p99_hrs(), "");
    }
    println!(
        "avg JCT improvement tune vs proportional: {:.2}x (paper: 1.5x)",
        avg[0] / avg[1]
    );
    println!(
        "p99 JCT improvement tune vs proportional: {:.2}x (paper: 2x)",
        p99[0] / p99[1]
    );
    println!(
        "tune within {:.1}% of opt on avg JCT (paper: ~4%)",
        (avg[1] / avg[2] - 1.0).abs() * 100.0
    );
}
