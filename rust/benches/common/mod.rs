//! Shared helpers for the figure benches.

use synergy::cluster::{ServerSpec, TopologySpec};
use synergy::job::Job;
use synergy::metrics::JctStats;
use synergy::sim::{SimConfig, SimResult, Simulator};
use synergy::trace::{generate, Split, TraceConfig};
#[allow(unused_imports)]
use synergy::workload::{
    PhillyTraceConfig, PhillyTraceSource, WorkloadSource,
};

/// Run one simulation with the given knobs and return the result.
pub fn run_sim(
    n_servers: usize,
    policy: &str,
    mechanism: &str,
    jobs: Vec<Job>,
) -> SimResult {
    run_sim_spec(ServerSpec::default(), n_servers, policy, mechanism, jobs)
}

pub fn run_sim_spec(
    spec: ServerSpec,
    n_servers: usize,
    policy: &str,
    mechanism: &str,
    jobs: Vec<Job>,
) -> SimResult {
    run_sim_ref(spec, None, n_servers, policy, mechanism, jobs)
}

/// Like [`run_sim_spec`] but with an explicit reference server shape for
/// the work accounting (Fig 12: durations are defined on ratio-3 servers
/// regardless of the SKU being simulated).
pub fn run_sim_ref(
    spec: ServerSpec,
    reference_spec: Option<ServerSpec>,
    n_servers: usize,
    policy: &str,
    mechanism: &str,
    jobs: Vec<Job>,
) -> SimResult {
    let sim = Simulator::new(SimConfig {
        spec,
        n_servers,
        round_s: 300.0,
        policy: policy.into(),
        mechanism: mechanism.into(),
        profile_noise: 0.0,
        max_sim_s: 500.0 * 86_400.0,
        span_factor: 1,
        network_penalty: 0.0,
        reference_spec,
        types: None,
        force_replan: false,
        no_resume: false,
        topology: TopologySpec::default(),
        shards: 1,
        faults: None,
    });
    sim.run(jobs)
}

/// A dynamic Philly-derived trace.
pub fn dynamic_trace(
    n_jobs: usize,
    load: f64,
    split: Split,
    multi_gpu: bool,
    seed: u64,
) -> Vec<Job> {
    generate(&TraceConfig {
        n_jobs,
        split,
        multi_gpu,
        jobs_per_hour: Some(load),
        seed,
    })
}

/// A static trace (all jobs at t=0).
pub fn static_trace(
    n_jobs: usize,
    split: Split,
    multi_gpu: bool,
    seed: u64,
) -> Vec<Job> {
    generate(&TraceConfig { n_jobs, split, multi_gpu, jobs_per_hour: None, seed })
}

/// Render jobs as a Philly-format CSV document (shared by the real-reader
/// bench path: generate → serialize → re-ingest through the CSV reader).
#[allow(dead_code)]
pub fn to_philly_csv(jobs: &[Job]) -> String {
    let mut out = String::from(
        "job_id,vc,submit_time,gpus,duration_s,model,status\n",
    );
    for j in jobs {
        out.push_str(&format!(
            "j{},t{},{},{},{},{},Pass\n",
            j.id.0,
            j.tenant.0,
            j.arrival_s,
            j.gpus,
            j.duration_prop_s,
            j.model.name()
        ));
    }
    out
}

/// A dynamic trace materialised as Philly CSV and read back through the
/// real reader path ([`PhillyTraceSource`]).
#[allow(dead_code)]
pub fn dynamic_trace_via_philly_reader(
    n_jobs: usize,
    load: f64,
    split: Split,
    multi_gpu: bool,
    seed: u64,
) -> Vec<Job> {
    let csv = to_philly_csv(&dynamic_trace(n_jobs, load, split, multi_gpu, seed));
    let mut src = PhillyTraceSource::from_str(
        &csv,
        &PhillyTraceConfig {
            duration_max_s: f64::INFINITY,
            gpu_cap: 16,
            seed,
            ..PhillyTraceConfig::default()
        },
    )
    .expect("re-ingest synthetic trace as Philly CSV");
    src.drain_jobs()
}

/// Steady-state JCT stats: drop warmup/cooldown jobs (first/last 15%).
pub fn steady_stats(result: &SimResult) -> JctStats {
    let mut finished = result.finished.clone();
    finished.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    let n = finished.len();
    let lo = n * 15 / 100;
    let hi = n - n * 15 / 100;
    let jcts: Vec<f64> =
        finished[lo..hi].iter().map(|f| f.jct_s).collect();
    JctStats::from_jcts(&jcts)
}
