//! Shared helpers for the figure benches.

use synergy::cluster::ServerSpec;
use synergy::job::Job;
use synergy::metrics::JctStats;
use synergy::sim::{SimConfig, SimResult, Simulator};
use synergy::trace::{generate, Split, TraceConfig};

/// Run one simulation with the given knobs and return the result.
pub fn run_sim(
    n_servers: usize,
    policy: &str,
    mechanism: &str,
    jobs: Vec<Job>,
) -> SimResult {
    run_sim_spec(ServerSpec::default(), n_servers, policy, mechanism, jobs)
}

pub fn run_sim_spec(
    spec: ServerSpec,
    n_servers: usize,
    policy: &str,
    mechanism: &str,
    jobs: Vec<Job>,
) -> SimResult {
    run_sim_ref(spec, None, n_servers, policy, mechanism, jobs)
}

/// Like [`run_sim_spec`] but with an explicit reference server shape for
/// the work accounting (Fig 12: durations are defined on ratio-3 servers
/// regardless of the SKU being simulated).
pub fn run_sim_ref(
    spec: ServerSpec,
    reference_spec: Option<ServerSpec>,
    n_servers: usize,
    policy: &str,
    mechanism: &str,
    jobs: Vec<Job>,
) -> SimResult {
    let sim = Simulator::new(SimConfig {
        spec,
        n_servers,
        round_s: 300.0,
        policy: policy.into(),
        mechanism: mechanism.into(),
        profile_noise: 0.0,
        max_sim_s: 500.0 * 86_400.0,
        span_factor: 1,
        network_penalty: 0.0,
        reference_spec,
    });
    sim.run(jobs)
}

/// A dynamic Philly-derived trace.
pub fn dynamic_trace(
    n_jobs: usize,
    load: f64,
    split: Split,
    multi_gpu: bool,
    seed: u64,
) -> Vec<Job> {
    generate(&TraceConfig {
        n_jobs,
        split,
        multi_gpu,
        jobs_per_hour: Some(load),
        seed,
    })
}

/// A static trace (all jobs at t=0).
pub fn static_trace(
    n_jobs: usize,
    split: Split,
    multi_gpu: bool,
    seed: u64,
) -> Vec<Job> {
    generate(&TraceConfig { n_jobs, split, multi_gpu, jobs_per_hour: None, seed })
}

/// Steady-state JCT stats: drop warmup/cooldown jobs (first/last 15%).
pub fn steady_stats(result: &SimResult) -> JctStats {
    let mut finished = result.finished.clone();
    finished.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    let n = finished.len();
    let lo = n * 15 / 100;
    let hi = n - n * 15 / 100;
    let jcts: Vec<f64> =
        finished[lo..hi].iter().map(|f| f.jct_s).collect();
    JctStats::from_jcts(&jcts)
}
