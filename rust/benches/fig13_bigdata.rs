//! Figure 13: comparison with big-data schedulers (DRF, Tetris) on
//! 128 GPUs, workloads W1 (20,70,10) and W2 (50,0,50).
//!
//! Naive DRF/Tetris = the policy's ordering with *static* best-case
//! demands packed first-fit (the `fixed` mechanism); the Synergy-variant
//! swaps in TUNE's fungible allocation. Paper: Synergy reduces avg JCT of
//! DRF by 7.2x and Tetris by 1.8x on W2.

mod common;

use common::{dynamic_trace, run_sim, steady_stats};
use synergy::trace::{Split, SPLIT_DEFAULT, SPLIT_WORST};
use synergy::util::bench::{row, section};

fn main() {
    let workloads: [(&str, Split, f64); 2] = [
        ("W1", SPLIT_DEFAULT, 4.0),
        ("W2", SPLIT_WORST, 3.0),
    ];
    for (wname, split, load) in workloads {
        section(&format!("Figure 13: workload {wname}"));
        let mut results = Vec::new();
        for (policy, mech, label) in [
            ("drf", "fixed", "DRF"),
            ("drf", "tune", "Synergy-DRF"),
            ("tetris", "fixed", "Tetris"),
            ("tetris", "tune", "Synergy-Tetris"),
            ("srtf", "tune", "Synergy-TUNE"),
        ] {
            let jobs = dynamic_trace(1200, load, split, true, 1300);
            let r = run_sim(16, policy, mech, jobs);
            let s = steady_stats(&r);
            let unfinished = 1200usize.saturating_sub(r.finished.len());
            row(
                "fig13",
                &format!("{wname}/{label}"),
                load,
                s.avg_hrs(),
                &format!("unfinished={unfinished}"),
            );
            results.push((label, s.avg_hrs()));
        }
        let get = |l: &str| {
            results.iter().find(|(n, _)| *n == l).map(|(_, v)| *v).unwrap()
        };
        println!(
            "{wname}: Synergy-DRF improves DRF {:.1}x; Synergy-Tetris improves Tetris {:.1}x",
            get("DRF") / get("Synergy-DRF"),
            get("Tetris") / get("Synergy-Tetris"),
        );
    }
    println!("(paper on W2: DRF 7.2x, Tetris 1.8x)");
}
