//! Figures 7, 8, 9: load sweeps on 128 GPUs, split (20,70,10).
//!
//!  - Fig 7: LAS, multi-GPU trace — avg JCT vs load + short/long CDT tails;
//!  - Fig 8: SRTF, multi-GPU trace — avg JCT vs load + CDFs;
//!  - Fig 9: FIFO, single-GPU trace — avg JCT vs load, with the
//!    Synergy-OPT upper-bound line and a CDF at 9 jobs/hr.
//!
//! Paper shape: TUNE ≤ proportional everywhere, up to 3.4x at high
//! single-GPU load, within 10% of OPT, and sustains higher load.

mod common;

use common::{dynamic_trace, run_sim, steady_stats};
use synergy::metrics::jct_cdf;
use synergy::trace::SPLIT_DEFAULT;
use synergy::util::bench::{row, section};

fn main() {
    let n_jobs = 2500;

    // ---- Fig 7 (LAS, multi-GPU) + Fig 8 (SRTF, multi-GPU) --------------
    for (fig, policy) in [("fig7", "las"), ("fig8", "srtf")] {
        section(&format!(
            "{fig}: {policy} multi-GPU avg JCT vs load (128 GPUs)"
        ));
        for mech in ["proportional", "tune", "opt"] {
            for load in [2.0, 3.0, 4.0, 5.0, 5.5] {
                // OPT solves an ILP every round; keep its traces shorter
                // (it is an upper-bound line, not a deployable mechanism).
                let n = if mech == "opt" { 700 } else { n_jobs };
                let jobs = dynamic_trace(
                    n, load, SPLIT_DEFAULT, true, 700 + load as u64,
                );
                let r = run_sim(16, policy, mech, jobs);
                let s = steady_stats(&r);
                row(
                    fig,
                    &format!("{policy}/{mech}"),
                    load,
                    s.avg_hrs(),
                    &format!("p95_h={:.2}", s.p95_s / 3600.0),
                );
            }
        }
    }

    // ---- Fig 9 (FIFO, single-GPU) ---------------------------------------
    section("fig9: FIFO single-GPU avg JCT vs load (128 GPUs)");
    let mut at_11: Vec<(String, f64)> = Vec::new();
    for mech in ["proportional", "tune", "opt"] {
        for load in [5.0, 7.0, 9.0, 10.0, 11.0, 12.0] {
            let n = if mech == "opt" { 700 } else { n_jobs };
            let jobs = dynamic_trace(n, load, SPLIT_DEFAULT, false, 900);
            let r = run_sim(16, "fifo", mech, jobs);
            let s = steady_stats(&r);
            row("fig9a", &format!("fifo/{mech}"), load, s.avg_hrs(), "");
            if load == 11.0 {
                at_11.push((mech.to_string(), s.avg_hrs()));
                // CDF at the paper's highlighted load.
                for (v, f) in jct_cdf(
                    &r.finished.iter().map(|x| x.jct_s).collect::<Vec<_>>(),
                    10,
                ) {
                    row(
                        "fig9b",
                        &format!("cdf/{mech}"),
                        f,
                        v / 3600.0,
                        "",
                    );
                }
            }
        }
    }
    if at_11.len() == 3 {
        println!(
            "\nat 11 jobs/hr: prop={:.1}h tune={:.1}h opt={:.1}h  \
             (paper: 81h -> 22h, opt 20h; ratio {:.1}x, tune within {:.0}% of opt)",
            at_11[0].1,
            at_11[1].1,
            at_11[2].1,
            at_11[0].1 / at_11[1].1,
            (at_11[1].1 / at_11[2].1 - 1.0).abs() * 100.0
        );
    }
}
