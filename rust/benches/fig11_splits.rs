//! Figure 11: impact of workload split (FIFO, multi-GPU, 128 GPUs).
//!
//! Three splits — (20,70,10), (30,60,10), (50,0,50) — comparing
//! GPU-proportional, Synergy-GREEDY, and Synergy-TUNE across load.
//!
//! Paper shape: as resource-sensitive jobs dominate, GREEDY collapses
//! (GPU fragmentation) while TUNE degrades gracefully to proportional.

mod common;

use common::{dynamic_trace, run_sim, steady_stats};
use synergy::trace::Split;
use synergy::util::bench::{row, section};

fn main() {
    let splits = [
        ("20-70-10", Split::new(20, 70, 10)),
        ("30-60-10", Split::new(30, 60, 10)),
        ("50-0-50", Split::new(50, 0, 50)),
    ];
    for (name, split) in splits {
        section(&format!("Figure 11: split {name}"));
        for mech in ["proportional", "greedy", "tune"] {
            for load in [2.0, 3.0, 4.0, 5.0] {
                let jobs = dynamic_trace(1500, load, split, true, 1100);
                let r = run_sim(16, "fifo", mech, jobs);
                let s = steady_stats(&r);
                // GREEDY may never finish some jobs within the cap; count.
                let unfinished = 1500usize.saturating_sub(r.finished.len());
                row(
                    "fig11",
                    &format!("{name}/{mech}"),
                    load,
                    s.avg_hrs(),
                    &format!("unfinished={unfinished}"),
                );
            }
        }
    }
}
