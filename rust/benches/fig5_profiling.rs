//! Figure 5 + §3.1: optimistic-profiling validation and cost.
//!
//! (a) Memory validation: profiler-estimated throughput vs ground truth
//!     for ResNet18 across memory allocations (paper: within 3%).
//! (b) CPU validation: estimated vs empirical normalized runtime across
//!     CPU allocations, and the number of empirical points used
//!     (paper: ~8 points instead of 24; 10x total profiling reduction).

use synergy::cluster::ServerSpec;
use synergy::job::{Job, JobId, ModelKind, ALL_MODELS};
use synergy::perf::PerfModel;
use synergy::profiler::{OptimisticProfiler, MINUTES_PER_POINT};
use synergy::util::bench::{row, section};

fn main() {
    let spec = ServerSpec::default();
    let world = PerfModel::new(spec);
    let profiler = OptimisticProfiler::new(spec); // with 3% noise, like real runs

    // (a) Memory validation for an 8-GPU ResNet18 job (Fig 5a setup).
    section("Figure 5a: memory validation (ResNet18, 8 GPUs, 24 CPUs)");
    let job = Job::new(JobId(0), ModelKind::ResNet18, 8, 0.0, 3600.0);
    let out = profiler.profile(&job);
    let matrix = out.primary();
    let mut worst: f64 = 0.0;
    for &m in &matrix.mem_points {
        let est = matrix.throughput_at(24.0, m);
        let truth = world.throughput(ModelKind::ResNet18, 8, 24.0, m);
        if truth > 0.0 {
            let err = (est - truth).abs() / truth;
            worst = worst.max(err);
            row("fig5a", "estimated", m, est, &format!("truth={truth:.0} err={:.1}%", err * 100.0));
        }
    }
    println!("worst relative error: {:.1}% (paper: within 3%)", worst * 100.0);

    // (b) CPU validation for a 1-GPU ResNet18 job (Fig 5b setup).
    section("Figure 5b: CPU validation (ResNet18, 1 GPU, full memory)");
    let job1 = Job::new(JobId(1), ModelKind::ResNet18, 1, 0.0, 3600.0);
    let out1 = profiler.profile(&job1);
    let matrix1 = out1.primary();
    let full_mem = *matrix1.mem_points.last().unwrap();
    let t1 = world.throughput(ModelKind::ResNet18, 1, 1.0, 1000.0);
    for &c in &matrix1.cpu_points {
        // normalized runtime wrt 1 CPU (as the paper plots)
        let est = t1 / matrix1.throughput_at(c, full_mem).max(1e-9);
        let truth =
            t1 / world.throughput(ModelKind::ResNet18, 1, c, 1000.0);
        row("fig5b", "normalized_runtime", c, est, &format!("truth={truth:.3}"));
    }
    println!(
        "empirical points: {} of 24 ({:.0} min vs 24 min exhaustive vs 240 min naive grid)",
        out1.empirical_points,
        out1.cost_minutes / MINUTES_PER_POINT
    );

    // §3.1 profiling cost across the zoo.
    section("profiling cost per model (1 GPU)");
    for m in ALL_MODELS {
        let j = Job::new(JobId(10 + m as u64), m, 1, 0.0, 3600.0);
        let o = profiler.profile(&j);
        row(
            "profiling_cost",
            m.name(),
            o.empirical_points as f64,
            o.cost_minutes,
            "grid_would_be=240min",
        );
    }
}
