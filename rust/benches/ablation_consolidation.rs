//! §6 ablation: the consolidation-vs-allocation tradeoff.
//!
//! The paper's main body pins multi-GPU jobs to "no more than a server's
//! worth of CPU or memory ... if its GPU demands can be satisfied by one
//! server" and flags the alternative — giving up consolidation for a
//! larger CPU/memory allocation — as future work. This ablation runs it:
//!
//! - `span_factor = 1` — consolidation-strict (paper default);
//! - `span_factor = 2` — allocation-greedy: multi-GPU jobs may claim up
//!   to two servers' worth of CPU/memory, splitting their gang;
//!
//! under a swept network penalty (per extra server: throughput divided
//! by `1 + p·(span−1)`). The expected shape: at p = 0, splitting helps
//! CPU-hungry image jobs; as p grows the gain inverts and the paper's
//! consolidation-strict default wins — exactly why §6 leaves the relaxed
//! policy to a network-aware future scheduler.

mod common;

use synergy::cluster::TopologySpec;
use synergy::sim::{SimConfig, Simulator};
use synergy::trace::{generate, Split, TraceConfig};
use synergy::util::bench::{row, section};

fn main() {
    // Image-heavy multi-GPU trace: the population that wants more than
    // one server's CPUs.
    let jobs = generate(&TraceConfig {
        n_jobs: 200,
        split: Split::new(70, 20, 10),
        multi_gpu: true,
        jobs_per_hour: Some(5.0),
        seed: 33,
    });

    section("§6 ablation: consolidation (span=1) vs allocation (span=2)");
    println!(
        "{:<10} {:>16} {:>16} {:>10}",
        "penalty", "strict avg JCT h", "greedy avg JCT h", "greedy/strict"
    );
    for penalty in [0.0, 0.05, 0.10, 0.20, 0.40, 0.80] {
        let mut avg = Vec::new();
        for span_factor in [1usize, 2] {
            let sim = Simulator::new(SimConfig {
                n_servers: 16,
                policy: "srtf".into(),
                mechanism: "tune".into(),
                span_factor,
                network_penalty: penalty,
                ..Default::default()
            });
            let r = sim.run(jobs.clone());
            assert_eq!(r.finished.len(), jobs.len(), "all jobs must finish");
            let s = r.jct_stats();
            row(
                "ablation/consolidation",
                &format!("span{span_factor}/p{penalty}"),
                penalty,
                s.avg_hrs(),
                "avg h",
            );
            avg.push(s.avg_hrs());
        }
        println!(
            "{:<10} {:>16.2} {:>16.2} {:>9.2}x",
            penalty,
            avg[0],
            avg[1],
            avg[1] / avg[0]
        );
    }

    // Locality ablation (ISSUE 7): the same gang-heavy trace on a
    // 16-server fleet split into 2 racks, with the rack-rank
    // consolidation score on vs off. Both arms charge the per-rack link
    // cost; only the packing order differs, so the aware arm should
    // place fewer cross-rack gangs and (when the link cost bites) win
    // on JCT.
    section("ISSUE 7 ablation: rack-aware vs rack-blind gang packing");
    println!(
        "{:<8} {:>14} {:>12} {:>18} {:>12}",
        "arm", "avg JCT h", "gangs", "cross-rack gangs", "cross frac"
    );
    for (tag, aware) in [("aware", true), ("blind", false)] {
        let sim = Simulator::new(SimConfig {
            n_servers: 16,
            policy: "srtf".into(),
            mechanism: "tune".into(),
            topology: TopologySpec {
                placement_aware: aware,
                ..TopologySpec::racks(2)
            },
            ..Default::default()
        });
        let r = sim.run(jobs.clone());
        assert_eq!(r.finished.len(), jobs.len(), "all jobs must finish");
        let s = r.jct_stats();
        row(
            "ablation/locality",
            &format!("racks2/{tag}/jct"),
            if aware { 1.0 } else { 0.0 },
            s.avg_hrs(),
            "avg h",
        );
        row(
            "ablation/locality",
            &format!("racks2/{tag}/cross_rack"),
            if aware { 1.0 } else { 0.0 },
            r.cross_rack_fraction(),
            "frac",
        );
        println!(
            "{:<8} {:>14.2} {:>12} {:>18} {:>11.3}",
            tag,
            s.avg_hrs(),
            r.gangs_placed,
            r.cross_rack_gangs,
            r.cross_rack_fraction()
        );
    }
}
