//! §3.1 profiling-cost bench: exhaustive vs optimistic vs adaptive.
//!
//! Paper numbers (24-CPU, 500 GB server; 1 min per empirical point):
//!
//! - exhaustive grid: 24 CPUs × 10 memory levels ≈ **240 min**;
//! - optimistic (memory axis analytic): 24 points ≈ **24 min**;
//! - + adaptive CPU sampling: ~8 points ≈ **8 min** (Fig 5b).
//!
//! This bench reports the measured point counts/costs per model and the
//! estimate accuracy the cheap profile retains (Fig 5 fidelity).

use synergy::cluster::ServerSpec;
use synergy::job::{Job, JobId, ALL_MODELS};
use synergy::perf::PerfModel;
use synergy::profiler::{OptimisticProfiler, MINUTES_PER_POINT};
use synergy::util::bench::{row, section};

fn main() {
    let spec = ServerSpec::default();
    let exhaustive_min =
        spec.cpus as f64 * (spec.mem_gb / 50.0) * MINUTES_PER_POINT;
    let optimistic_min = spec.cpus as f64 * MINUTES_PER_POINT;

    section("§3.1 profiling cost per 1-GPU job (minutes)");
    println!(
        "exhaustive grid: {exhaustive_min:.0} min   \
         optimistic (CPU-only): {optimistic_min:.0} min   (paper: 240 / 24)"
    );

    let profiler = OptimisticProfiler::noiseless(spec);
    let world = PerfModel::new(spec);
    let mut total_points = 0usize;
    for model in ALL_MODELS {
        let job = Job::new(JobId(1), model, 1, 0.0, 3600.0);
        let out = profiler.profile(&job);
        total_points += out.empirical_points;

        // Fig-5 fidelity: worst relative error of the estimate vs truth
        // across the whole grid.
        let matrix = out.primary();
        let mut worst: f64 = 0.0;
        for (ci, &c) in matrix.cpu_points.iter().enumerate() {
            for (mi, &m) in matrix.mem_points.iter().enumerate() {
                let truth = world.throughput(model, 1, c, m);
                if truth > 0.0 {
                    worst = worst
                        .max((matrix.tput[ci][mi] - truth).abs() / truth);
                }
            }
        }
        row(
            "profiling",
            model.name(),
            out.cost_minutes,
            worst * 100.0,
            "min / worst-err %",
        );
    }
    let adaptive_min = total_points as f64 / ALL_MODELS.len() as f64;
    println!(
        "adaptive mean: {adaptive_min:.1} min/job — \
         {:.0}x cheaper than exhaustive (paper: 30x), \
         {:.1}x cheaper than optimistic (paper: ~3x)",
        exhaustive_min / adaptive_min,
        optimistic_min / adaptive_min,
    );
}
