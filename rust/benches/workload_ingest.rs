//! Workload-ingestion throughput: jobs/second through (a) the Philly
//! CSV parser, (b) the Alibaba adapter, and (c) tenant-quota admission.
//!
//! ```bash
//! cargo bench --bench workload_ingest
//! ```
//!
//! Writes the measured numbers to `BENCH_workload.json` at the repo root
//! so later PRs can track the ingestion hot path.

mod common;

use common::to_philly_csv;
use synergy::trace::{generate, TraceConfig, SPLIT_DEFAULT};
use synergy::util::bench::{section, Bench};
use synergy::util::json::Json;
use synergy::workload::{
    admit, AdmissionJob, AlibabaTraceConfig, AlibabaTraceSource,
    PhillyTraceConfig, PhillyTraceSource, TenantQuotas, WorkloadSource,
};
use synergy::job::TenantId;

const N_JOBS: usize = 50_000;

fn alibaba_csv(rows: usize) -> String {
    // Deterministic arithmetic pattern; content volume is what matters.
    let mut out = String::from(
        "timestamp,machine_id,cpu_util_percent,mem_util_percent\n",
    );
    for i in 0..rows {
        let cpu = (i * 37) % 100;
        let mem = (i * 53) % 100;
        out.push_str(&format!(
            "{},m_{},{cpu},{mem}\n",
            i * 7,
            i % 64,
        ));
    }
    out
}

fn main() {
    section("workload ingestion throughput");
    let jobs = generate(&TraceConfig {
        n_jobs: N_JOBS,
        split: SPLIT_DEFAULT,
        multi_gpu: true,
        jobs_per_hour: Some(36.0),
        seed: 99,
    });
    let philly_doc = to_philly_csv(&jobs);
    let ali_doc = alibaba_csv(N_JOBS);

    let bench = Bench::default();

    // (a) Philly CSV: parse + normalize + sort + spec conversion.
    let t_philly = bench.iter("philly_csv/parse_50k", || {
        let mut src = PhillyTraceSource::from_str(
            &philly_doc,
            &PhillyTraceConfig::default(),
        )
        .unwrap();
        let jobs = src.drain_jobs();
        assert_eq!(jobs.len(), N_JOBS);
        jobs
    });
    let philly_jps = N_JOBS as f64 / t_philly.median.as_secs_f64();

    // (b) Alibaba adapter.
    let t_ali = bench.iter("alibaba_csv/parse_50k", || {
        let mut src = AlibabaTraceSource::from_str(
            &ali_doc,
            &AlibabaTraceConfig::default(),
        )
        .unwrap();
        let jobs = src.drain_jobs();
        assert_eq!(jobs.len(), N_JOBS);
        jobs
    });
    let ali_jps = N_JOBS as f64 / t_ali.median.as_secs_f64();

    // (c) Quota admission over the full queue (8 tenants, 512 GPUs).
    let queue: Vec<AdmissionJob> = jobs
        .iter()
        .map(|j| AdmissionJob {
            id: j.id,
            tenant: TenantId((j.id.0 % 8) as u32),
            gpus: j.gpus,
        })
        .collect();
    let mut quotas = TenantQuotas::new();
    for t in 0..8 {
        quotas.set(TenantId(t), (t + 1) as f64);
    }
    let t_admit = bench.iter("admission/quota_50k_queue", || {
        let out = admit(&queue, 512, Some(&quotas));
        assert!(!out.admitted.is_empty());
        out
    });
    let admit_jps = N_JOBS as f64 / t_admit.median.as_secs_f64();

    println!(
        "\nphilly_parse={philly_jps:.0} jobs/s  alibaba_parse={ali_jps:.0} \
         jobs/s  quota_admission={admit_jps:.0} jobs/s"
    );

    // Persist for later PRs.
    let doc = Json::obj(vec![
        ("bench", Json::str("workload_ingest")),
        ("n_jobs", Json::num(N_JOBS as f64)),
        ("philly_parse_jobs_per_s", Json::num(philly_jps)),
        ("alibaba_parse_jobs_per_s", Json::num(ali_jps)),
        ("quota_admission_jobs_per_s", Json::num(admit_jps)),
        (
            "philly_parse_median_ms",
            Json::num(t_philly.median.as_secs_f64() * 1e3),
        ),
        (
            "alibaba_parse_median_ms",
            Json::num(t_ali.median.as_secs_f64() * 1e3),
        ),
        (
            "quota_admission_median_ms",
            Json::num(t_admit.median.as_secs_f64() * 1e3),
        ),
    ])
    .encode();
    let out_path =
        format!("{}/../BENCH_workload.json", env!("CARGO_MANIFEST_DIR"));
    match std::fs::write(&out_path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
