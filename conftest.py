"""Pytest path shim: make `compile.*` importable when pytest is invoked
from the repository root (`pytest python/tests/`) as well as from
`python/` (`cd python && python -m pytest tests/`)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
