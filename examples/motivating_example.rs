//! The paper's motivating example (§2.1, Tables 1-3, Figure 3).
//!
//! Four 4-GPU jobs (ResNet18, Audio-M5, Transformer, GNMT) on two
//! 8-GPU/24-CPU/500-GB servers, scheduled two ways:
//!
//!  - Schedule 1: GPU-proportional — every job gets 12 CPUs, 250 GB;
//!  - Schedule 2: resource-sensitive — Synergy-TUNE redistributes.
//!
//! The paper reports the disproportionate schedule cutting average JCT by
//! ~1.5x; this example prints both allocation tables and the speedup.
//!
//! ```bash
//! cargo run --release --example motivating_example
//! ```

use synergy::cluster::{Fleet, ServerSpec};
use synergy::coordinator::RoundPlanner;
use synergy::job::{Job, JobId, ModelKind};
use synergy::mechanism::{by_name, Grant};
use synergy::perf::PerfModel;
use synergy::policy::Fifo;
use synergy::profiler::{OptimisticProfiler, Sensitivity};
use std::collections::BTreeMap;

// One epoch's worth of samples, for reporting epoch time like Fig 3.
fn epoch_samples(model: ModelKind) -> f64 {
    match model.task() {
        synergy::job::Task::Image => 1_281_167.0, // ImageNet
        synergy::job::Task::Language => 400_000.0,
        synergy::job::Task::Speech => 500_000.0,
    }
}

fn run_schedule(mechanism: &str) -> (BTreeMap<JobId, Grant>, Vec<(JobId, ModelKind, f64)>) {
    let spec = ServerSpec::default();
    let mut fleet = Fleet::homogeneous(spec, 2);
    let profiler = OptimisticProfiler::noiseless(spec);
    let world = PerfModel::new(spec);

    let jobs: Vec<Job> = [
        (1u64, ModelKind::ResNet18),
        (2, ModelKind::M5),
        (3, ModelKind::TransformerXl),
        (4, ModelKind::Gnmt),
    ]
    .iter()
    .map(|&(id, m)| Job::new(JobId(id), m, 4, 0.0, 3600.0))
    .collect();

    let ctxs: Vec<Sensitivity> = jobs
        .iter()
        .map(|j| profiler.profile(j))
        .collect();
    let refs: Vec<(&Job, &Sensitivity)> = jobs.iter().zip(ctxs.iter()).collect();
    let planner = RoundPlanner::new(
        Box::new(Fifo),
        by_name(mechanism).expect("mechanism"),
    );
    let plan = planner.plan(&mut fleet, &refs, 0.0);

    let mut epochs = Vec::new();
    for j in &jobs {
        let g = &plan.grants[&j.id];
        let tput =
            world.throughput(j.model, j.gpus, g.demand.cpus, g.demand.mem_gb);
        epochs.push((j.id, j.model, epoch_samples(j.model) / tput / 3600.0));
    }
    (plan.grants, epochs)
}

fn main() {
    println!("Motivating example: 4 jobs x 4 GPUs on 2 servers (Tables 1-3)\n");
    let mut avg = Vec::new();
    for (label, mech) in
        [("Table 2: GPU-proportional", "proportional"), ("Table 3: resource-sensitive (TUNE)", "tune")]
    {
        let (grants, epochs) = run_schedule(mech);
        println!("{label}");
        println!("  {:<6} {:<14} {:>5} {:>6} {:>8}", "job", "model", "GPU", "CPU", "Mem(GB)");
        for (id, model, _) in &epochs {
            let g = &grants[id];
            println!(
                "  J{:<5} {:<14} {:>5} {:>6.0} {:>8.0}",
                id.0, model.name(), g.demand.gpus, g.demand.cpus, g.demand.mem_gb
            );
        }
        println!("  {:<6} {:<14} {:>12}", "job", "model", "epoch_time(h)");
        let mut total = 0.0;
        for (id, model, e) in &epochs {
            println!("  J{:<5} {:<14} {:>12.2}", id.0, model.name(), e);
            total += e;
        }
        let mean = total / epochs.len() as f64;
        println!("  average epoch time: {mean:.2} h\n");
        avg.push(mean);
    }
    println!(
        "resource-sensitive scheduling improves average epoch time by {:.2}x (paper: ~1.5x)",
        avg[0] / avg[1]
    );
}
