//! End-to-end driver: train the AOT-compiled transformer through the full
//! three-layer stack (Pallas kernels -> JAX train step -> HLO text ->
//! rust PJRT runtime) and log the loss curve.
//!
//! Proves all layers compose: the Layer-1 fused-attention/LayerNorm
//! kernels execute inside the Layer-2 train-step HLO, driven entirely
//! from rust with device-resident parameters.
//!
//! ```bash
//! cargo run --release --example e2e_train -- [--variant gpt100m]
//!     [--steps 300] [--lr 0.2] [--out e2e_loss.csv]
//! ```
//!
//! Defaults train the ~100M-parameter `gpt100m` variant for 300 steps on
//! the synthetic bigram corpus; the loss must fall well below the
//! ln(vocab) uniform baseline. Results are recorded in EXPERIMENTS.md.

use synergy::runtime::{Runtime, SyntheticCorpus, Trainer};
use synergy::util::cli::Args;
use std::io::Write;
use std::time::Instant;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let variant = args.get_or("variant", "gpt100m").to_string();
    let steps = args.usize("steps", 300);
    let lr = args.f64("lr", 0.2) as f32;
    let artifacts = args.get_or("artifacts", "artifacts").to_string();
    let out_path = args.get_or("out", "e2e_loss.csv").to_string();

    println!("e2e_train: variant={variant} steps={steps} lr={lr}");
    let t0 = Instant::now();
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let (meta, exe) = rt
        .load_variant(&artifacts, &variant)
        .expect("load artifact (run `make artifacts` first)");
    println!(
        "loaded {}: {:.1}M params, batch={} seq={} vocab={} (compile {:?})",
        meta.variant,
        meta.param_count as f64 / 1e6,
        meta.batch,
        meta.seq_len,
        meta.vocab,
        t0.elapsed()
    );
    let uniform = (meta.vocab as f64).ln();
    let mut corpus = SyntheticCorpus::new(meta.vocab, 7);
    let mut trainer =
        Trainer::new(&rt.client, exe, meta, 0).expect("trainer init");

    let mut csv = String::from("step,loss,seconds\n");
    let train_start = Instant::now();
    let mut first = f64::NAN;
    let mut last = f64::NAN;
    for step in 1..=steps {
        let toks = corpus.batch(trainer.meta.batch, trainer.meta.seq_len);
        let loss = trainer.train_step(&toks, lr).expect("train step") as f64;
        if step == 1 {
            first = loss;
        }
        last = loss;
        csv.push_str(&format!(
            "{step},{loss:.4},{:.2}\n",
            train_start.elapsed().as_secs_f64()
        ));
        if step == 1 || step % 25 == 0 {
            println!(
                "step {step:>4}  loss {loss:>7.4}  (uniform baseline {uniform:.3})  {:.2} s/step",
                train_start.elapsed().as_secs_f64() / step as f64
            );
        }
    }
    std::fs::File::create(&out_path)
        .and_then(|mut f| f.write_all(csv.as_bytes()))
        .expect("write loss csv");

    let sps = steps as f64 / train_start.elapsed().as_secs_f64();
    println!(
        "\ndone: loss {first:.3} -> {last:.3} over {steps} steps \
         ({sps:.2} steps/s); curve in {out_path}"
    );
    assert!(
        last < first && last < uniform,
        "loss must descend below the uniform baseline"
    );
    println!("loss curve OK (descending, below ln(V)={uniform:.2})");
}
