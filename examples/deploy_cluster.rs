//! Live mini-cluster (the paper's physical-cluster experiment, §5.2 /
//! Table 5, scaled to one host): a leader and two workers run a small
//! trace with real PJRT training on the workers, then the *same trace*
//! replays on the simulator to demonstrate deploy/simulate fidelity.
//!
//! ```bash
//! cargo run --release --example deploy_cluster -- [--jobs 12]
//!     [--variant tiny] [--time-scale 900] [--no-compute]
//! ```

use synergy::deploy::{Leader, LeaderConfig, Worker, WorkerConfig};
use synergy::sim::{SimConfig, Simulator};
use synergy::trace::{generate, Split, TraceConfig};
use synergy::util::cli::Args;
use std::sync::Arc;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let n_jobs = args.usize("jobs", 12);
    let variant = args.get_or("variant", "tiny").to_string();
    let time_scale = args.f64("time-scale", 900.0);
    let real_compute = !args.flag("no-compute");
    let n_workers = args.usize("workers", 2);

    let trace_cfg = TraceConfig {
        n_jobs,
        split: Split::new(30, 60, 10),
        multi_gpu: false,
        jobs_per_hour: None, // static trace, FIFO — the Table-5 setup
        seed: 5,
    };
    let jobs = generate(&trace_cfg);

    // --- deploy -----------------------------------------------------------
    let leader = Arc::new(Leader::new(LeaderConfig {
        bind: "127.0.0.1:0".into(),
        n_workers,
        round_real_s: 1.0,
        time_scale,
        policy: "fifo".into(),
        mechanism: "tune".into(),
        variant,
        max_real_s: args.f64("max-real", 300.0),
        quotas: None,
        telemetry: args.get("telemetry").map(str::to_string),
        telemetry_timing: false,
    }));
    let l2 = Arc::clone(&leader);
    let trace_for_deploy = jobs.clone();
    let leader_thread =
        std::thread::spawn(move || l2.run(trace_for_deploy).expect("leader"));

    // Wait for the leader to bind, then start workers.
    let addr = loop {
        if let Some(a) = *leader.addr.lock().unwrap() {
            break a;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    };
    let mut worker_threads = Vec::new();
    for _ in 0..n_workers {
        let cfg = WorkerConfig {
            leader_addr: addr.to_string(),
            artifacts_dir: "artifacts".into(),
            real_compute,
            ..Default::default()
        };
        worker_threads.push(std::thread::spawn(move || Worker::run(cfg)));
    }
    let report = leader_thread.join().expect("leader thread");
    for t in worker_threads {
        let _ = t.join();
    }

    let deploy_stats = report.jct_stats();
    println!(
        "\ndeploy:   {} jobs finished, {} rounds, {} real train steps",
        deploy_stats.n, report.rounds, report.total_steps
    );
    println!(
        "deploy:   avg JCT {:.2} h (sim-time)  makespan {:.2} h",
        deploy_stats.avg_hrs(),
        report.makespan_sim_s / 3600.0
    );
    if !report.losses.is_empty() {
        let mean_loss: f64 =
            report.losses.values().sum::<f64>() / report.losses.len() as f64;
        println!("deploy:   mean final training loss {mean_loss:.3}");
    }

    // --- simulate the same trace (Table 5 fidelity check) ------------------
    let sim = Simulator::new(SimConfig {
        n_servers: n_workers,
        policy: "fifo".into(),
        mechanism: "tune".into(),
        ..Default::default()
    });
    let sim_result = sim.run(jobs);
    let sim_stats = sim_result.jct_stats();
    println!(
        "simulate: avg JCT {:.2} h  makespan {:.2} h",
        sim_stats.avg_hrs(),
        sim_result.makespan_s / 3600.0
    );
    if deploy_stats.n > 0 {
        let diff = (deploy_stats.avg_s - sim_stats.avg_s).abs()
            / sim_stats.avg_s.max(1e-9)
            * 100.0;
        println!("deploy-vs-simulate avg JCT difference: {diff:.1}%");
    }
}
