//! Heterogeneous-cluster walkthrough (paper Appendix A.2) — on the one
//! type-generic stack: the same profiler, mechanisms and simulator that
//! run the homogeneous examples, handed a two-generation fleet.
//!
//! Builds a P100 + V100 fleet, profiles a small mixed workload along the
//! machine-type dimension, and shows how TUNE's type assignment routes
//! compute-bound jobs to fast GPUs while input-bound jobs — which cannot
//! exploit them — keep the slower generation, then runs a full trace
//! through the heterogeneous front-end of the shared simulator.
//!
//! Run with: `cargo run --release --example heterogeneous`

use synergy::cluster::{Fleet, GpuGen};
use synergy::hetero::{HeteroSimConfig, HeteroSimulator};
use synergy::job::{Job, JobId, ModelKind};
use synergy::mechanism::{JobRequest, Mechanism, Tune};
use synergy::profiler::{OptimisticProfiler, Sensitivity};
use synergy::trace::{generate, Split, TraceConfig};

fn main() {
    // --- 1. profile a job per machine type ---------------------------------
    let fleet = Fleet::two_tier(2);
    let profiler = OptimisticProfiler::noiseless_fleet(&fleet);
    println!("Per-type peak throughput (samples/s, 1 GPU):");
    println!("{:<16} {:>10} {:>10} {:>8}", "model", "p100", "v100", "gain");
    for model in [
        ModelKind::Gnmt,
        ModelKind::TransformerXl,
        ModelKind::ResNet18,
        ModelKind::ShuffleNetV2,
    ] {
        let job = Job::new(JobId(0), model, 1, 0.0, 3600.0);
        let s = profiler.profile(&job);
        let slow = s.matrix(GpuGen::P100).unwrap().max_throughput();
        let fast = s.matrix(GpuGen::V100).unwrap().max_throughput();
        println!(
            "{:<16} {:>10.1} {:>10.1} {:>7.2}x",
            model.name(),
            slow,
            fast,
            fast / slow
        );
    }
    println!();

    // --- 2. one round of TUNE type assignment -------------------------------
    let mut fleet = Fleet::two_tier(1);
    let jobs: Vec<Job> = [
        (0, ModelKind::Gnmt, 8),         // compute-bound -> fast type
        (1, ModelKind::ShuffleNetV2, 8), // input-bound   -> slow type
    ]
    .iter()
    .map(|&(id, m, g)| Job::new(JobId(id), m, g, 0.0, 3600.0))
    .collect();
    let sens: Vec<Sensitivity> = jobs.iter().map(|j| profiler.profile(j)).collect();
    let reqs: Vec<JobRequest<'_>> = jobs
        .iter()
        .zip(&sens)
        .map(|(j, s)| JobRequest { id: j.id, gpus: j.gpus, sens: s })
        .collect();
    let grants = Tune::default().allocate(&mut fleet, &reqs);
    println!("TUNE type assignment:");
    for j in &jobs {
        let g = &grants[&j.id];
        println!(
            "  {:<16} -> {:<5} ({} GPUs, {:.0} CPUs, {:.0} GB)",
            j.model.name(),
            g.gen.name(),
            j.gpus,
            g.demand.cpus,
            g.demand.mem_gb
        );
    }
    println!();

    // --- 3. full trace through the heterogeneous front-end ------------------
    let trace = generate(&TraceConfig {
        n_jobs: 120,
        split: Split::new(30, 50, 20),
        multi_gpu: true,
        jobs_per_hour: Some(6.0),
        seed: 42,
    });
    println!("Simulating 120 jobs on 64 P100 + 64 V100 GPUs (SRTF):");
    for mech in ["het-proportional", "het-tune"] {
        let r = HeteroSimulator::new(HeteroSimConfig {
            mechanism: mech.into(),
            ..Default::default()
        })
        .run(trace.clone());
        let s = r.jct_stats();
        println!(
            "  {:<18} avg JCT {:>6.2} h   p99 {:>7.2} h   ({} rounds)",
            mech,
            s.avg_hrs(),
            s.p99_hrs(),
            r.rounds
        );
    }
}
