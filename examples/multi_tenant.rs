//! Multi-tenant scheduling: tenant-tagged workloads, weighted GPU
//! quotas, and per-tenant JCT/fairness reporting.
//!
//! ```bash
//! cargo run --release --example multi_tenant
//! ```
//!
//! Two tenants share a 16-GPU cluster. `prod` holds a 3× GPU quota over
//! `research`, but both submit the same backlog. The example runs the
//! same trace with and without quota admission and prints how the
//! weighted shares reshape per-tenant JCTs, plus Jain's fairness index
//! over the tenants' average JCTs. It finishes by replaying the same
//! workload through the Philly-format CSV reader to show the two
//! ingestion paths are interchangeable.

use synergy::job::TenantId;
use synergy::metrics::jains_index;
use synergy::sim::{SimConfig, SimResult, Simulator};
use synergy::trace::{Split, TraceConfig};
use synergy::workload::{
    PhillyTraceConfig, PhillyTraceSource, SyntheticSource, TenantQuotas,
    TenantSpec, WorkloadSource,
};

fn report(tag: &str, names: &[String], result: &SimResult) {
    println!("--- {tag} ---");
    let by = result.tenant_stats();
    for (t, s) in &by {
        println!(
            "  {:<10} jobs={:<3} avg_jct={:>6.2}h p99={:>6.2}h",
            names[t.0 as usize],
            s.n,
            s.avg_hrs(),
            s.p99_hrs()
        );
    }
    let avgs: Vec<f64> = by.values().map(|s| s.avg_s).collect();
    println!("  jain_fairness(avg_jct) = {:.3}\n", jains_index(&avgs));
}

fn main() {
    // 1:1 job assignment between the tenants (equal backlogs).
    let assign = TenantSpec::parse("prod,research").unwrap();
    let names = assign.names.clone();
    let trace_cfg = TraceConfig {
        n_jobs: 80,
        split: Split::new(30, 60, 10),
        multi_gpu: false,
        jobs_per_hour: None, // static burst: full contention
        seed: 42,
    };
    let jobs = SyntheticSource::new(trace_cfg)
        .with_tenants(assign)
        .drain_jobs();
    let sim_cfg = || SimConfig {
        n_servers: 2, // 16 GPUs
        policy: "srtf".into(),
        mechanism: "tune".into(),
        ..Default::default()
    };

    println!(
        "multi-tenant demo: 16 GPUs, {} jobs, equal backlogs\n",
        jobs.len()
    );

    // No quotas: tenants compete purely through the policy order.
    let plain = Simulator::new(sim_cfg()).run(jobs.clone());
    report("no quotas (policy order only)", &names, &plain);

    // prod holds a 3x GPU quota; spill keeps it work-conserving.
    let quotas = TenantQuotas::new()
        .with(TenantId(0), 3.0)
        .with(TenantId(1), 1.0);
    let quoted =
        Simulator::with_quotas(sim_cfg(), Some(quotas)).run(jobs.clone());
    report("prod:3 research:1 quotas", &names, &quoted);

    // The same jobs through the Philly CSV reader: write, re-ingest, run.
    let csv = {
        let mut out = String::from(
            "job_id,vc,submit_time,gpus,duration_s,model,status\n",
        );
        for j in &jobs {
            out.push_str(&format!(
                "j{},{},{},{},{},{},Pass\n",
                j.id.0,
                names[j.tenant.0 as usize],
                j.arrival_s,
                j.gpus,
                j.duration_prop_s,
                j.model.name()
            ));
        }
        out
    };
    let mut src = PhillyTraceSource::from_str(
        &csv,
        &PhillyTraceConfig::default(),
    )
    .expect("re-ingest");
    let csv_names = src.tenant_names();
    let spec = TenantSpec::parse("prod:3,research:1").unwrap();
    let replayed = Simulator::with_quotas(
        sim_cfg(),
        Some(spec.quotas_for(&csv_names)),
    )
    .run(src.drain_jobs());
    report("same workload via Philly CSV reader", &csv_names, &replayed);
}
