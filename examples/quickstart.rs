//! Quickstart: schedule a small workload with Synergy-TUNE and compare
//! against GPU-proportional allocation.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use synergy::sim::{SimConfig, Simulator};
use synergy::trace::{generate, Split, TraceConfig};

fn main() {
    // A 4-server (32-GPU) cluster, 100 jobs arriving at 8 jobs/hour with
    // the paper's (30, 60, 10) image/language/speech split.
    let trace = generate(&TraceConfig {
        n_jobs: 100,
        split: Split::new(30, 60, 10),
        multi_gpu: true,
        jobs_per_hour: Some(8.0),
        seed: 42,
    });

    println!("synergy quickstart: 32 GPUs, 100 jobs, SRTF policy\n");
    let mut results = Vec::new();
    for mechanism in ["proportional", "tune"] {
        let sim = Simulator::new(SimConfig {
            n_servers: 4,
            policy: "srtf".into(),
            mechanism: mechanism.into(),
            ..Default::default()
        });
        let result = sim.run(trace.clone());
        let stats = result.jct_stats();
        println!(
            "{:<14} avg JCT {:>6.2} h   p99 {:>6.2} h   mean CPU util {:>5.1}%",
            mechanism,
            stats.avg_hrs(),
            stats.p99_hrs(),
            result.utilization.mean_cpu_util() * 100.0
        );
        results.push(stats.avg_s);
    }
    println!(
        "\nSynergy-TUNE improves average JCT by {:.2}x over GPU-proportional",
        results[0] / results[1]
    );
}
